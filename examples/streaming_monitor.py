"""Continuous acoustic monitoring with the streaming in-filter pipeline.

The paper's deployment story: audio goes in at the sensor, ONLY class
decisions come out (remote monitoring over limited bandwidth). This example
trains an ``InFilterPipeline`` on synthetic ESC-10 clips, then simulates a
long environmental recording by concatenating held-out clips and pushes it
through the stateful streaming API in sensor-sized chunks (10 ms frames).
The state — FIR delay lines, decimator phases, per-band accumulators — is a
few KB regardless of how long the stream runs, exactly the FPGA's register
footprint.

    PYTHONPATH=src python examples/streaming_monitor.py [--fast]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.filterbank import FilterBankConfig
from repro.core.pipeline import InFilterPipeline
from repro.core.trainer import TrainConfig
from repro.data.acoustic import ESC10_CLASSES, make_esc10_like


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    fs = 4000.0 if args.fast else 8000.0
    octaves = 4 if args.fast else 5
    per_tr = 4 if args.fast else 12

    # 1. train the deployable pipeline: taps + classifier + statistics in one
    ds = make_esc10_like(per_class_train=per_tr, per_class_test=2,
                         fs=fs, seconds=0.5, seed=0)
    cfg = FilterBankConfig(fs=fs, num_octaves=octaves, filters_per_octave=5,
                           mode="mp", gamma_f=4.0)
    pipe, losses = InFilterPipeline.fit(
        cfg, ds.x_train, ds.y_train, num_classes=10,
        train_cfg=TrainConfig(num_steps=150 if args.fast else 400))
    print(f"trained: loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
          f"{pipe.num_bands} bands")

    # 2. one-shot check on the held-out clips (the whole path is one jit)
    predict = jax.jit(pipe.predict)
    p = predict(jnp.asarray(ds.x_test))
    acc = float((np.asarray(jnp.argmax(p, -1)) == ds.y_test).mean())
    print(f"one-shot test acc: {acc:.3f}")

    # 3. continuous mode: a 'long recording' of back-to-back events, chunked
    #    into 10 ms frames — one session slot per event so each decision is
    #    clean. The slot-batched SessionState carries FIR delay lines,
    #    per-slot decimator phases, accumulators, and the running amax;
    #    apply() is the same entry point as the one-shot call above.
    order = np.argsort(ds.y_test, kind="stable")
    stream = jnp.asarray(ds.x_test[order])            # (E, N) events
    chunk = int(fs * 0.010)                           # 10 ms sensor frames
    apply_fn = jax.jit(InFilterPipeline.apply)
    state = pipe.init_session(stream.shape[0])
    n = stream.shape[1]
    for i in range(0, n, chunk):
        p_now, state = apply_fn(pipe, stream[:, i:i + chunk], state)
    pred = np.asarray(jnp.argmax(p_now, -1))
    truth = ds.y_test[order]
    acc_stream = float((pred == truth).mean())
    state_bytes = sum(int(np.prod(a.shape)) * a.dtype.itemsize
                      for a in jax.tree.leaves(state))
    print(f"streamed  test acc: {acc_stream:.3f} "
          f"({n // chunk} chunks of {chunk} samples, "
          f"state = {state_bytes / stream.shape[0]:.0f} B/stream)")
    for e in range(0, stream.shape[0], max(1, stream.shape[0] // 5)):
        print(f"  event {e}: true={ESC10_CLASSES[truth[e]]:14s} "
              f"decided={ESC10_CLASSES[pred[e]]:14s} "
              f"confidence={float(p_now[e, pred[e]]):+.2f}")

    # 4. deployment-shaped serving: the same events as LOGICAL sessions on a
    #    fixed-capacity StreamServer — sensors come and go, the server
    #    multiplexes them onto slots and one compiled call advances all
    #    resident streams per packet
    from repro.serving import StreamServer
    events = np.asarray(stream)
    server = StreamServer(pipe, capacity=min(4, events.shape[0]),
                          max_chunk=max(16, 1 << (chunk - 1).bit_length()))
    ids = [f"sensor-{e}" for e in range(server.capacity)]
    for sid in ids:
        server.open(sid)
    results = []
    for i in range(0, n, chunk):
        results = server.feed([(sid, events[e, i:i + chunk])
                               for e, sid in enumerate(ids)])
    ok = sum(r.label == truth[e] for e, r in enumerate(results))
    print(f"served    {len(ids)} sessions x {n // chunk} packets: "
          f"{ok}/{len(ids)} correct, stats={server.stats()}")


if __name__ == "__main__":
    main()
