"""Quickstart: the paper's technique in 30 lines.

Builds a multiplierless MP kernel-machine classifier on synthetic acoustic
data: FIR filter bank (feature extractor == kernel) in the MP domain, then
MP classification with gamma-annealed training.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core.filterbank import FilterBank, FilterBankConfig
from repro.core import trainer
from repro.data.acoustic import make_esc10_like


def main():
    # 1. data: ESC-10-like synthetic environmental sounds
    ds = make_esc10_like(per_class_train=8, per_class_test=4,
                         fs=8000.0, seconds=0.5)

    # 2. in-filter feature extraction: the FIR bank IS the kernel (MP mode:
    #    every filter is computed with add/compare/shift only)
    fb = FilterBank(FilterBankConfig(fs=8000.0, num_octaves=5,
                                     filters_per_octave=5,
                                     mode="mp", gamma_f=4.0))
    feat = jax.jit(fb.accumulate)
    s_tr = feat(jnp.asarray(ds.x_train))
    mu, sd = s_tr.mean(0), s_tr.std(0, ddof=1) + 1e-6
    K_tr = (s_tr - mu) / sd                       # Phi, eq. (13)
    K_te = (feat(jnp.asarray(ds.x_test)) - mu) / sd

    # 3. MP kernel machine (eq. 2-7) trained through the approximation
    params, losses = trainer.train(
        K_tr, jnp.asarray(ds.y_train), num_classes=10,
        cfg=trainer.TrainConfig(num_steps=300, lr=0.5))

    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    print("train acc:", trainer.evaluate(params, K_tr, jnp.asarray(ds.y_train)))
    print("test  acc:", trainer.evaluate(params, K_te, jnp.asarray(ds.y_test)))
    print("test  acc @8-bit:", trainer.evaluate(params, K_te,
                                                jnp.asarray(ds.y_test),
                                                quant_bits=8))


if __name__ == "__main__":
    main()
