"""End-to-end driver: train a ~100M-parameter LM with the full framework
stack (sharded train step, AdamW + cosine, checkpointing, deterministic
data shards, straggler monitor) on CPU.

Default is a CPU-budget run (a few hundred steps of a ~10M model); pass
--full-100m for the ~100M configuration (slow on CPU — the same command on
a TPU host runs as-is).

    PYTHONPATH=src python examples/lm_train.py --steps 200
    PYTHONPATH=src python examples/lm_train.py --full-100m --steps 300
"""

import argparse
import dataclasses

from repro.launch import train as train_launcher
from repro.models.transformer import ArchConfig


def small_lm(full_100m: bool) -> ArchConfig:
    if full_100m:
        # ~100M params: 12L x 768 (GPT-2-small-ish) with a qwen3 flavour
        return ArchConfig(
            name="lm-100m", family="dense", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, d_ff=3072, vocab_size=32768,
            qk_norm=True, remat=False, q_chunk=256, kv_chunk=256)
    return ArchConfig(
        name="lm-10m", family="dense", num_layers=4, d_model=256,
        num_heads=8, num_kv_heads=4, d_ff=1024, vocab_size=8192,
        qk_norm=True, remat=False, q_chunk=128, kv_chunk=128)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/lm_train_ckpt")
    args = ap.parse_args()

    cfg = small_lm(args.full_100m)
    from repro.models import transformer as T
    import jax
    n = T.param_count(jax.eval_shape(
        lambda: T.init(cfg, jax.random.PRNGKey(0))))
    print(f"model: {cfg.name}, {n/1e6:.1f}M params")

    # reuse the production launcher end to end (monkey-patching its config
    # source so the exact same code path as `python -m repro.launch.train`
    # is exercised)
    import repro.launch.train as tl
    orig = tl.get_smoke
    tl.get_smoke = lambda _: cfg
    try:
        losses = tl.main([
            "--arch", "qwen3-8b", "--smoke",
            "--steps", str(args.steps),
            "--batch", str(args.batch),
            "--seq", str(args.seq),
            "--lr", "3e-3", "--warmup", "50",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        ])
    finally:
        tl.get_smoke = orig
    assert losses[-1] < losses[0], "training must reduce loss"
    print("OK: loss decreased "
          f"{losses[0]:.3f} -> {min(losses):.3f}")


if __name__ == "__main__":
    main()
