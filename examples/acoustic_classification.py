"""End-to-end reproduction of the paper's deployment flow.

1. Train the MP in-filter classifier (float) with gamma annealing.
2. Quantize everything to 8-bit fixed point (taps + weights), Fig. 8 style.
3. Compare against the MAC 'Normal SVM' baseline (Table III columns).
4. Run the deployed model through the Pallas in-filter kernel path
   (fir_mp_accumulate: FIR + HWR + accumulate fused, single pass).

    PYTHONPATH=src python examples/acoustic_classification.py [--fast]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.filterbank import FilterBank, FilterBankConfig
from repro.core import kernel_machine as km
from repro.core import trainer
from repro.core.trainer import _maybe_quant
from repro.data.acoustic import ESC10_CLASSES, make_esc10_like


def pipeline(mode, qbits, ds, fs, octaves, use_pallas=False):
    fb = FilterBank(FilterBankConfig(fs=fs, num_octaves=octaves,
                                     filters_per_octave=5, mode=mode,
                                     gamma_f=4.0, quant_bits=qbits,
                                     use_pallas=use_pallas))
    feat = jax.jit(fb.accumulate)
    s_tr = feat(jnp.asarray(ds.x_train))
    mu, sd = s_tr.mean(0), s_tr.std(0, ddof=1) + 1e-6
    K_tr = (s_tr - mu) / sd
    K_te = (feat(jnp.asarray(ds.x_test)) - mu) / sd
    params, _ = trainer.train(
        K_tr, jnp.asarray(ds.y_train), 10,
        trainer.TrainConfig(num_steps=400, lr=0.5, quant_bits=qbits))
    acc = trainer.evaluate(params, K_te, jnp.asarray(ds.y_test), qbits)
    return acc, params, (mu, sd), fb


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    fs, octaves = (4000.0, 4) if args.fast else (8000.0, 5)
    per_tr, per_te = (6, 3) if args.fast else (16, 8)
    ds = make_esc10_like(per_class_train=per_tr, per_class_test=per_te,
                         fs=fs, seconds=0.5, seed=0)

    print("=== MAC baseline ('Normal SVM' column) ===")
    acc_mac, *_ = pipeline("mac", None, ds, fs, octaves)
    print(f"test acc: {acc_mac:.3f}")

    print("=== MP in-filter, float ===")
    acc_mp, *_ = pipeline("mp", None, ds, fs, octaves)
    print(f"test acc: {acc_mp:.3f}")

    print("=== MP in-filter, 8-bit fixed point (deployment) ===")
    acc_q8, params, (mu, sd), fb = pipeline("mp", 8, ds, fs, octaves)
    print(f"test acc: {acc_q8:.3f}")

    print("=== deployed inference through the fused Pallas kernel ===")
    fbk = FilterBank(fb.config._replace(use_pallas=True))
    feat = jax.jit(fbk.accumulate)
    t0 = time.time()
    K = (feat(jnp.asarray(ds.x_test)) - mu) / sd
    p = km.forward(_maybe_quant(params, 8), K, 1.0)
    pred = np.asarray(jnp.argmax(p, -1))
    dt = time.time() - t0
    acc_kernel = float((pred == ds.y_test).mean())
    print(f"pallas-path test acc: {acc_kernel:.3f} "
          f"({len(ds.y_test)/dt:.1f} clips/s on CPU interpret mode)")
    print("\nper-class (one-vs-all) @8-bit:")
    for c, name in enumerate(ESC10_CLASSES):
        ova = float(((np.asarray(p)[:, c] > 0) == (ds.y_test == c)).mean())
        print(f"  {name:16s} {ova:.3f}")


if __name__ == "__main__":
    main()
