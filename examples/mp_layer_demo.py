"""The paper's technique as a first-class LM layer mode: run the same tiny
transformer with standard matmuls and with multiplierless MP projections
(eq. 9 through the fused Pallas kernel), and train the MP version a few
steps — demonstrating that backprop through the water-filling works at the
transformer scale too.

    PYTHONPATH=src python examples/mp_layer_demo.py
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.steps import make_train_step
from repro.models.transformer import ArchConfig
from repro.models import transformer as T
from repro.optim import AdamWConfig


def main():
    base = ArchConfig(
        name="mp-demo", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
        remat=False, q_chunk=32, kv_chunk=32)

    toks = jax.random.randint(jax.random.PRNGKey(0), (4, 32), 0, 512)
    batch = {"tokens": toks}

    params = T.init(base, jax.random.PRNGKey(1))
    logits_std = T.forward(params, base, batch)

    mp_cfg = dataclasses.replace(base, mp_mode=True, mp_gamma=8.0)
    logits_mp = T.forward(params, mp_cfg, batch)
    print("standard logits std :", float(logits_std.std()))
    print("MP-mode logits std  :", float(logits_mp.std()))
    print("(different by design — MP approximates each inner product; "
          "training absorbs the error:)")

    init_state, train_step = make_train_step(
        mp_cfg, AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=20))
    state = init_state(jax.random.PRNGKey(1))
    step = jax.jit(train_step)
    losses = []
    for _ in range(10):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    print("MP-mode training loss:", " -> ".join(f"{l:.3f}" for l in losses[::3]))
    assert losses[-1] < losses[0]
    print("OK: backprop through the MP water-filling trains the transformer")


if __name__ == "__main__":
    main()
