/* Generated fixed-point reference — see repro.ir.cgen. Do not edit. */
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>

static int32_t add32(int32_t a, int32_t b) {
    return (int32_t)((uint32_t)a + (uint32_t)b);
}
static int32_t sub32(int32_t a, int32_t b) {
    return (int32_t)((uint32_t)a - (uint32_t)b);
}
static int32_t neg32(int32_t a) { return (int32_t)(0u - (uint32_t)a); }
static int32_t min32(int32_t a, int32_t b) { return a < b ? a : b; }
static int32_t max32(int32_t a, int32_t b) { return a > b ? a : b; }
static int32_t abs32(int32_t a) { return a < 0 ? neg32(a) : a; }
static int32_t sign32(int32_t a) { return a > 0 ? 1 : (a < 0 ? -1 : 0); }
static int32_t shl32(int32_t x, int32_t k) {
    if (k >= 32 || k < 0) return 0;
    return (int32_t)((uint32_t)x << k);
}
static int32_t asr32(int32_t x, int32_t k) {
    if (k < 0) k = 0;
    if (k >= 32) return x < 0 ? -1 : 0;
    if (k == 0) return x;
    {
        uint32_t s = (uint32_t)x >> k;
        if (x < 0) s |= ~(uint32_t)0 << (32 - k);
        return (int32_t)s;
    }
}
static int32_t shrl32(int32_t x, int32_t k) {
    if (k >= 32 || k < 0) return 0;
    return (int32_t)((uint32_t)x >> k);
}
static long clamp_start(long s, long dim, long size) {
    if (s < 0) s = 0;
    if (s > dim - size) s = dim - size;
    return s;
}

static const int32_t rom0_c[80] = {
    2, 0, -7, 1, 17, -10, -25, 20, 20, -25, -10, 17,
    1, -7, 0, 2, -2, 2, 1, -12, 11, 9, -29, 16,
    16, -29, 9, 11, -12, 1, 2, -2, 0, -3, 6, -5,
    -7, 22, -28, 12, 12, -28, 22, -7, -5, 6, -3, 0,
    0, 0, -4, 10, -19, 22, -20, 7, 7, -20, 22, -19,
    10, -4, 0, 0, -6, 7, -14, 21, -26, 25, -19, 7,
    7, -19, 25, -26, 21, -14, 7, -6
};
static const int32_t rom1_c[6] = {
    -1, 8, 56, 56, 8, -1
};
static const int32_t rom2_c[30] = {
    0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0,
    0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0,
    0, 0, 0, 0, 0, 0
};
static const int32_t rom3_c[30] = {
    -3, -3, -3, -3, -3, -3, -3, -3, -3, -3, -3, -3,
    -3, -3, -3, -3, -3, -3, -3, -3, -3, -3, -3, -3,
    -3, -3, -3, -3, -3, -3
};
static const int32_t rom4_c[30] = {
    -4, -4, -4, -4, -4, -4, -4, -4, -4, -4, -4, -4,
    -4, -4, -4, -4, -4, -4, -4, -4, -4, -4, -4, -4,
    -4, -4, -4, -4, -4, -4
};
static const int32_t rom5_c[300] = {
    11, 10, 13, 13, 2, 9, 3, 14, 6, 13, 6, 6,
    10, 6, 5, 1, 5, 12, 5, 3, 3, 15, 12, 10,
    14, 0, 12, 12, 2, 2, 8, 0, 2, 14, 14, 0,
    1, 2, 1, 12, 10, 10, 6, 5, 14, 8, 6, 15,
    5, 15, 12, 5, 4, 13, 9, 1, 6, 1, 5, 10,
    10, 9, 1, 16, 5, 9, 0, 7, 9, 7, 13, 12,
    4, 6, 9, 15, 11, 8, 1, 7, 10, 2, 14, 6,
    8, 10, 11, 9, 14, 9, 13, 13, 1, 9, 12, 5,
    0, 0, 11, 8, 7, 11, 15, 14, 1, 6, 14, 12,
    10, 11, 0, 15, 5, 2, 15, 15, 7, 11, 4, 8,
    12, 13, 12, 1, 6, 5, 6, 6, 14, 3, 14, 3,
    2, 9, 3, 12, 15, 13, 4, 6, 5, 8, 6, 0,
    2, 7, 3, 2, 14, 13, 2, 15, 8, 5, 8, 8,
    13, 12, 7, 1, 10, 2, 10, 15, 4, 15, 1, 0,
    5, 1, 11, 15, 5, 11, 15, 9, 11, 2, 1, 5,
    14, 15, 6, 10, 8, 15, 1, 2, 2, 0, 5, 5,
    8, 4, 12, 7, 6, 3, 12, 0, 0, 6, 7, 3,
    2, 6, 0, 10, 3, 5, 0, 4, 13, 15, 14, 16,
    3, 10, 9, 14, 4, 12, 1, 9, 1, 13, 2, 0,
    1, 5, 5, 0, 15, 14, 15, 16, 3, 5, 8, 12,
    15, 3, 12, 1, 15, 13, 6, 15, 3, 0, 14, 3,
    4, 4, 2, 9, 6, 6, 7, 9, 1, 15, 12, 8,
    5, 3, 2, 8, 4, 4, 9, 0, 14, 15, 12, 6,
    14, 5, 14, 2, 6, 14, 15, 0, 11, 0, 7, 15,
    5, 10, 0, 6, 2, 5, 6, 7, 2, 11, 6, 9
};
static const int32_t rom6_c[300] = {
    3, 2, 15, 6, 8, 8, 0, 15, 13, 7, 15, 13,
    12, 12, 10, 7, 7, 8, 5, 6, 11, 11, 10, 1,
    3, 8, 12, 5, 1, 6, 12, 4, 10, 7, 1, 9,
    15, 13, 11, 2, 11, 13, 1, 0, 1, 6, 5, 16,
    4, 12, 8, 3, 4, 7, 14, 7, 7, 5, 15, 12,
    15, 2, 9, 8, 14, 6, 1, 3, 3, 0, 9, 4,
    7, 12, 10, 16, 11, 1, 4, 11, 13, 1, 14, 2,
    8, 10, 8, 2, 2, 12, 2, 7, 4, 9, 9, 6,
    4, 5, 2, 9, 11, 8, 12, 1, 7, 4, 0, 9,
    13, 12, 5, 4, 12, 3, 8, 14, 7, 2, 8, 9,
    12, 10, 8, 0, 15, 11, 15, 12, 8, 15, 9, 5,
    7, 13, 1, 11, 12, 11, 11, 3, 2, 12, 0, 5,
    15, 2, 9, 14, 4, 2, 13, 8, 1, 7, 2, 13,
    4, 6, 13, 7, 0, 10, 3, 7, 14, 7, 1, 15,
    9, 11, 11, 8, 9, 13, 11, 12, 0, 6, 6, 6,
    12, 10, 10, 12, 2, 6, 2, 6, 3, 15, 2, 3,
    15, 13, 0, 3, 12, 5, 7, 3, 7, 16, 4, 10,
    6, 5, 5, 1, 5, 13, 12, 0, 12, 6, 1, 11,
    0, 14, 5, 7, 2, 14, 7, 9, 13, 12, 2, 9,
    0, 3, 9, 5, 14, 15, 14, 7, 0, 1, 8, 9,
    14, 16, 8, 7, 12, 4, 7, 10, 5, 15, 8, 1,
    3, 12, 2, 11, 2, 4, 16, 11, 5, 12, 8, 4,
    12, 7, 7, 11, 3, 0, 9, 7, 7, 8, 6, 3,
    7, 4, 12, 7, 4, 14, 14, 14, 5, 14, 7, 13,
    11, 14, 9, 8, 12, 2, 1, 12, 3, 9, 0, 0
};
static const int32_t rom7_c[10] = {
    0, 0, 0, 0, 0, 0, 0, 0, 0, 0
};
static const int32_t rom8_lit[1] = {
    1
};
static const int32_t rom9_lit[1] = {
    0
};
static const uint8_t rom10_lit[1] = {
    0
};
static const int32_t rom11_lit[1] = {
    16399
};
static const int32_t rom12_lit[1] = {
    1039
};
static const int32_t rom13_lit[1] = {
    -512
};
static const int32_t rom14_lit[1] = {
    511
};
static const int32_t rom15_lit[1] = {
    512
};
static const int32_t rom16_lit[1] = {
    16389
};
static const int32_t rom17_lit[1] = {
    1029
};
static const int32_t rom18_lit[1] = {
    -128
};
static const int32_t rom19_lit[1] = {
    127
};
static const int32_t rom20_lit[1] = {
    8207
};
static const int32_t rom21_lit[1] = {
    8197
};
static const int32_t rom22_lit[1] = {
    4111
};
static const int32_t rom23_lit[1] = {
    2
};
static const int32_t rom24_lit[1] = {
    4101
};
static const int32_t rom25_lit[1] = {
    2063
};
static const int32_t rom26_lit[1] = {
    3
};
static const int32_t rom27_lit[1] = {
    2053
};
static const int32_t rom28_lit[1] = {
    1015
};
static const int32_t rom29_lit[1] = {
    4
};
static const int32_t rom30_lit[1] = {
    1005
};
static const int32_t rom31_lit[1] = {
    515
};
static const int32_t rom32_lit[1] = {
    5
};
static const int32_t rom33_lit[1] = {
    256
};
static const int32_t rom34_lit[1] = {
    32
};

static int32_t r0[16000];
static const int32_t *const r1 = rom0_c;
static const int32_t *const r2 = rom1_c;
static const int32_t *const r3 = rom2_c;
static const int32_t *const r4 = rom3_c;
static const int32_t *const r5 = rom4_c;
static const int32_t *const r6 = rom5_c;
static const int32_t *const r7 = rom6_c;
static const int32_t *const r8 = rom7_c;
static const int32_t *const r9 = rom8_lit;
static int32_t r10[16000];
static int32_t r11[80];
static int32_t r12[80];
static int32_t r13[80];
static const int32_t *const r14 = rom9_lit;
static int32_t r15[1];
static int32_t r16[16015];
static int32_t r17[1];
static int32_t r18[16399];
static int32_t r19[1024];
static int32_t r20[1024];
static int32_t r21[16];
static int32_t r22[16];
static int32_t r23[16384];
static int32_t r24[16];
static int32_t r25[16];
static int32_t r26[16399];
static int32_t r27[16384];
static int32_t r28[80];
static int32_t r29[1];
static int32_t r30[1];
static const uint8_t *const r31 = rom10_lit;
static int32_t r32[1];
static uint8_t r33[1];
static const int32_t *const r34 = rom11_lit;
static int32_t r35[1];
static int32_t r36[1];
static int32_t r37[1039];
static uint8_t r38[16384];
static const int32_t *const r39 = rom12_lit;
static int32_t r40[16384];
static int32_t r41[16384];
static int32_t r42[16384];
static int32_t r43[16384];
static int32_t r44[16384];
static int32_t r45[81920];
static const int32_t *const r46 = rom13_lit;
static const int32_t *const r47 = rom14_lit;
static int32_t r48[1];
static int32_t r49[81920];
static int32_t r50[1];
static int32_t r51[81920];
static int32_t r52[81920];
static int32_t r53[1];
static int32_t r54[81920];
static int32_t r55[1];
static int32_t r56[81920];
static int32_t r57[81920];
static int32_t r58[5120];
static const int32_t *const r59 = rom15_lit;
static int32_t r60[5120];
static int32_t r61[81920];
static int32_t r62[1];
static int32_t r63[1];
static int32_t r64[5120];
static int32_t r65[5120];
static int32_t r66[1];
static int32_t r67[5120];
static int32_t r68[5120];
static int32_t r69[5120];
static int32_t r70[81920];
static int32_t r71[81920];
static int32_t r72[5120];
static int32_t r73[81920];
static int32_t r74[5120];
static int32_t r75[81920];
static int32_t r76[81920];
static int32_t r77[5120];
static int32_t r78[5120];
static uint8_t r79[5120];
static int32_t r80[5120];
static int32_t r81[5120];
static int32_t r82[1];
static int32_t r83[5120];
static int32_t r84[5120];
static int32_t r85[81920];
static int32_t r86[5120];
static int32_t r87[5120];
static int32_t r88[81920];
static int32_t r89[1];
static int32_t r90[1];
static int32_t r91[5120];
static int32_t r92[5120];
static int32_t r93[1];
static int32_t r94[5120];
static int32_t r95[5120];
static int32_t r96[5120];
static int32_t r97[81920];
static int32_t r98[81920];
static int32_t r99[5120];
static int32_t r100[81920];
static int32_t r101[5120];
static int32_t r102[81920];
static int32_t r103[81920];
static int32_t r104[5120];
static int32_t r105[5120];
static uint8_t r106[5120];
static int32_t r107[5120];
static int32_t r108[5120];
static int32_t r109[1];
static int32_t r110[5120];
static int32_t r111[5120];
static int32_t r112[5120];
static int32_t r113[81920];
static int32_t r114[81920];
static int32_t r115[81920];
static int32_t r116[80000];
static int32_t r117[80000];
static int32_t r118[80000];
static int32_t r119[5];
static int32_t r120[5];
static int32_t r121[16000];
static int32_t r122[6];
static int32_t r123[6];
static int32_t r124[6];
static int32_t r125[1];
static int32_t r126[16005];
static int32_t r127[1];
static int32_t r128[16389];
static int32_t r129[1024];
static int32_t r130[1024];
static int32_t r131[6];
static int32_t r132[6];
static int32_t r133[6144];
static int32_t r134[16];
static int32_t r135[16];
static int32_t r136[16389];
static int32_t r137[6144];
static int32_t r138[6];
static int32_t r139[1];
static int32_t r140[1];
static int32_t r141[1];
static uint8_t r142[1];
static const int32_t *const r143 = rom16_lit;
static int32_t r144[1];
static int32_t r145[1];
static int32_t r146[1029];
static uint8_t r147[6144];
static const int32_t *const r148 = rom17_lit;
static int32_t r149[6144];
static int32_t r150[6144];
static int32_t r151[6144];
static int32_t r152[6144];
static int32_t r153[6144];
static int32_t r154[6144];
static int32_t r155[1];
static int32_t r156[6144];
static int32_t r157[1];
static int32_t r158[6144];
static int32_t r159[6144];
static int32_t r160[1];
static int32_t r161[6144];
static int32_t r162[1];
static int32_t r163[6144];
static int32_t r164[6144];
static int32_t r165[1024];
static int32_t r166[1024];
static int32_t r167[6144];
static int32_t r168[1];
static int32_t r169[1];
static int32_t r170[1024];
static int32_t r171[1024];
static int32_t r172[1];
static int32_t r173[1024];
static int32_t r174[1024];
static int32_t r175[1024];
static int32_t r176[6144];
static int32_t r177[6144];
static int32_t r178[1024];
static int32_t r179[6144];
static int32_t r180[1024];
static int32_t r181[6144];
static int32_t r182[6144];
static int32_t r183[1024];
static int32_t r184[1024];
static uint8_t r185[1024];
static int32_t r186[1024];
static int32_t r187[1024];
static int32_t r188[1];
static int32_t r189[1024];
static int32_t r190[1024];
static int32_t r191[6144];
static int32_t r192[1024];
static int32_t r193[1024];
static int32_t r194[6144];
static int32_t r195[1];
static int32_t r196[1];
static int32_t r197[1024];
static int32_t r198[1024];
static int32_t r199[1];
static int32_t r200[1024];
static int32_t r201[1024];
static int32_t r202[1024];
static int32_t r203[6144];
static int32_t r204[6144];
static int32_t r205[1024];
static int32_t r206[6144];
static int32_t r207[1024];
static int32_t r208[6144];
static int32_t r209[6144];
static int32_t r210[1024];
static int32_t r211[1024];
static uint8_t r212[1024];
static int32_t r213[1024];
static int32_t r214[1024];
static int32_t r215[1];
static int32_t r216[1024];
static int32_t r217[1024];
static int32_t r218[1024];
static int32_t r219[16384];
static int32_t r220[16384];
static int32_t r221[16384];
static int32_t r222[16000];
static int32_t r223[16000];
static int32_t r224[16000];
static int32_t r225[16000];
static int32_t r226[16000];
static const int32_t *const r227 = rom18_lit;
static const int32_t *const r228 = rom19_lit;
static int32_t r229[1];
static int32_t r230[16000];
static int32_t r231[1];
static int32_t r232[16000];
static int32_t r233[8000];
static int32_t r234[8000];
static int32_t r235[8000];
static int32_t r236[8000];
static int32_t r237[8000];
static int32_t r238[8000];
static int32_t r239[80];
static int32_t r240[80];
static int32_t r241[80];
static int32_t r242[1];
static int32_t r243[8015];
static int32_t r244[1];
static int32_t r245[8207];
static int32_t r246[1024];
static int32_t r247[1024];
static int32_t r248[16];
static int32_t r249[16];
static int32_t r250[16384];
static int32_t r251[8];
static int32_t r252[8];
static int32_t r253[8207];
static int32_t r254[16384];
static int32_t r255[80];
static int32_t r256[1];
static int32_t r257[1];
static int32_t r258[1];
static uint8_t r259[1];
static const int32_t *const r260 = rom20_lit;
static int32_t r261[1];
static int32_t r262[1];
static int32_t r263[1039];
static uint8_t r264[16384];
static int32_t r265[16384];
static int32_t r266[16384];
static int32_t r267[16384];
static int32_t r268[16384];
static int32_t r269[16384];
static int32_t r270[81920];
static int32_t r271[1];
static int32_t r272[81920];
static int32_t r273[1];
static int32_t r274[81920];
static int32_t r275[81920];
static int32_t r276[1];
static int32_t r277[81920];
static int32_t r278[1];
static int32_t r279[81920];
static int32_t r280[81920];
static int32_t r281[5120];
static int32_t r282[5120];
static int32_t r283[81920];
static int32_t r284[1];
static int32_t r285[1];
static int32_t r286[5120];
static int32_t r287[5120];
static int32_t r288[1];
static int32_t r289[5120];
static int32_t r290[5120];
static int32_t r291[5120];
static int32_t r292[81920];
static int32_t r293[81920];
static int32_t r294[5120];
static int32_t r295[81920];
static int32_t r296[5120];
static int32_t r297[81920];
static int32_t r298[81920];
static int32_t r299[5120];
static int32_t r300[5120];
static uint8_t r301[5120];
static int32_t r302[5120];
static int32_t r303[5120];
static int32_t r304[1];
static int32_t r305[5120];
static int32_t r306[5120];
static int32_t r307[81920];
static int32_t r308[5120];
static int32_t r309[5120];
static int32_t r310[81920];
static int32_t r311[1];
static int32_t r312[1];
static int32_t r313[5120];
static int32_t r314[5120];
static int32_t r315[1];
static int32_t r316[5120];
static int32_t r317[5120];
static int32_t r318[5120];
static int32_t r319[81920];
static int32_t r320[81920];
static int32_t r321[5120];
static int32_t r322[81920];
static int32_t r323[5120];
static int32_t r324[81920];
static int32_t r325[81920];
static int32_t r326[5120];
static int32_t r327[5120];
static uint8_t r328[5120];
static int32_t r329[5120];
static int32_t r330[5120];
static int32_t r331[1];
static int32_t r332[5120];
static int32_t r333[5120];
static int32_t r334[5120];
static int32_t r335[40960];
static int32_t r336[40960];
static int32_t r337[40960];
static int32_t r338[40000];
static int32_t r339[40000];
static int32_t r340[40000];
static int32_t r341[5];
static int32_t r342[5];
static int32_t r343[8000];
static int32_t r344[6];
static int32_t r345[6];
static int32_t r346[6];
static int32_t r347[1];
static int32_t r348[8005];
static int32_t r349[1];
static int32_t r350[8197];
static int32_t r351[1024];
static int32_t r352[1024];
static int32_t r353[6];
static int32_t r354[6];
static int32_t r355[6144];
static int32_t r356[8];
static int32_t r357[8];
static int32_t r358[8197];
static int32_t r359[6144];
static int32_t r360[6];
static int32_t r361[1];
static int32_t r362[1];
static int32_t r363[1];
static uint8_t r364[1];
static const int32_t *const r365 = rom21_lit;
static int32_t r366[1];
static int32_t r367[1];
static int32_t r368[1029];
static uint8_t r369[6144];
static int32_t r370[6144];
static int32_t r371[6144];
static int32_t r372[6144];
static int32_t r373[6144];
static int32_t r374[6144];
static int32_t r375[6144];
static int32_t r376[1];
static int32_t r377[6144];
static int32_t r378[1];
static int32_t r379[6144];
static int32_t r380[6144];
static int32_t r381[1];
static int32_t r382[6144];
static int32_t r383[1];
static int32_t r384[6144];
static int32_t r385[6144];
static int32_t r386[1024];
static int32_t r387[1024];
static int32_t r388[6144];
static int32_t r389[1];
static int32_t r390[1];
static int32_t r391[1024];
static int32_t r392[1024];
static int32_t r393[1];
static int32_t r394[1024];
static int32_t r395[1024];
static int32_t r396[1024];
static int32_t r397[6144];
static int32_t r398[6144];
static int32_t r399[1024];
static int32_t r400[6144];
static int32_t r401[1024];
static int32_t r402[6144];
static int32_t r403[6144];
static int32_t r404[1024];
static int32_t r405[1024];
static uint8_t r406[1024];
static int32_t r407[1024];
static int32_t r408[1024];
static int32_t r409[1];
static int32_t r410[1024];
static int32_t r411[1024];
static int32_t r412[6144];
static int32_t r413[1024];
static int32_t r414[1024];
static int32_t r415[6144];
static int32_t r416[1];
static int32_t r417[1];
static int32_t r418[1024];
static int32_t r419[1024];
static int32_t r420[1];
static int32_t r421[1024];
static int32_t r422[1024];
static int32_t r423[1024];
static int32_t r424[6144];
static int32_t r425[6144];
static int32_t r426[1024];
static int32_t r427[6144];
static int32_t r428[1024];
static int32_t r429[6144];
static int32_t r430[6144];
static int32_t r431[1024];
static int32_t r432[1024];
static uint8_t r433[1024];
static int32_t r434[1024];
static int32_t r435[1024];
static int32_t r436[1];
static int32_t r437[1024];
static int32_t r438[1024];
static int32_t r439[1024];
static int32_t r440[8192];
static int32_t r441[8192];
static int32_t r442[8192];
static int32_t r443[8000];
static int32_t r444[8000];
static int32_t r445[8000];
static int32_t r446[8000];
static int32_t r447[8000];
static int32_t r448[1];
static int32_t r449[8000];
static int32_t r450[1];
static int32_t r451[8000];
static int32_t r452[4000];
static int32_t r453[4000];
static int32_t r454[4000];
static int32_t r455[4000];
static int32_t r456[4000];
static int32_t r457[4000];
static int32_t r458[80];
static int32_t r459[80];
static int32_t r460[80];
static int32_t r461[1];
static int32_t r462[4015];
static int32_t r463[1];
static int32_t r464[4111];
static int32_t r465[1024];
static int32_t r466[1024];
static int32_t r467[16];
static int32_t r468[16];
static int32_t r469[16384];
static int32_t r470[4];
static int32_t r471[4];
static int32_t r472[4111];
static int32_t r473[16384];
static int32_t r474[80];
static int32_t r475[1];
static int32_t r476[1];
static int32_t r477[1];
static uint8_t r478[1];
static const int32_t *const r479 = rom22_lit;
static int32_t r480[1];
static int32_t r481[1];
static int32_t r482[1039];
static uint8_t r483[16384];
static int32_t r484[16384];
static int32_t r485[16384];
static int32_t r486[16384];
static int32_t r487[16384];
static int32_t r488[16384];
static int32_t r489[81920];
static int32_t r490[1];
static int32_t r491[81920];
static int32_t r492[1];
static int32_t r493[81920];
static int32_t r494[81920];
static int32_t r495[1];
static int32_t r496[81920];
static int32_t r497[1];
static int32_t r498[81920];
static int32_t r499[81920];
static int32_t r500[5120];
static int32_t r501[5120];
static int32_t r502[81920];
static int32_t r503[1];
static int32_t r504[1];
static int32_t r505[5120];
static int32_t r506[5120];
static int32_t r507[1];
static int32_t r508[5120];
static int32_t r509[5120];
static int32_t r510[5120];
static int32_t r511[81920];
static int32_t r512[81920];
static int32_t r513[5120];
static int32_t r514[81920];
static int32_t r515[5120];
static int32_t r516[81920];
static int32_t r517[81920];
static int32_t r518[5120];
static int32_t r519[5120];
static uint8_t r520[5120];
static int32_t r521[5120];
static int32_t r522[5120];
static int32_t r523[1];
static int32_t r524[5120];
static int32_t r525[5120];
static int32_t r526[81920];
static int32_t r527[5120];
static int32_t r528[5120];
static int32_t r529[81920];
static int32_t r530[1];
static int32_t r531[1];
static int32_t r532[5120];
static int32_t r533[5120];
static int32_t r534[1];
static int32_t r535[5120];
static int32_t r536[5120];
static int32_t r537[5120];
static int32_t r538[81920];
static int32_t r539[81920];
static int32_t r540[5120];
static int32_t r541[81920];
static int32_t r542[5120];
static int32_t r543[81920];
static int32_t r544[81920];
static int32_t r545[5120];
static int32_t r546[5120];
static uint8_t r547[5120];
static int32_t r548[5120];
static int32_t r549[5120];
static int32_t r550[1];
static int32_t r551[5120];
static int32_t r552[5120];
static int32_t r553[5120];
static int32_t r554[20480];
static int32_t r555[20480];
static int32_t r556[20480];
static int32_t r557[20000];
static int32_t r558[20000];
static int32_t r559[20000];
static int32_t r560[5];
static const int32_t *const r561 = rom23_lit;
static int32_t r562[5];
static int32_t r563[4000];
static int32_t r564[6];
static int32_t r565[6];
static int32_t r566[6];
static int32_t r567[1];
static int32_t r568[4005];
static int32_t r569[1];
static int32_t r570[4101];
static int32_t r571[1024];
static int32_t r572[1024];
static int32_t r573[6];
static int32_t r574[6];
static int32_t r575[6144];
static int32_t r576[4];
static int32_t r577[4];
static int32_t r578[4101];
static int32_t r579[6144];
static int32_t r580[6];
static int32_t r581[1];
static int32_t r582[1];
static int32_t r583[1];
static uint8_t r584[1];
static const int32_t *const r585 = rom24_lit;
static int32_t r586[1];
static int32_t r587[1];
static int32_t r588[1029];
static uint8_t r589[6144];
static int32_t r590[6144];
static int32_t r591[6144];
static int32_t r592[6144];
static int32_t r593[6144];
static int32_t r594[6144];
static int32_t r595[6144];
static int32_t r596[1];
static int32_t r597[6144];
static int32_t r598[1];
static int32_t r599[6144];
static int32_t r600[6144];
static int32_t r601[1];
static int32_t r602[6144];
static int32_t r603[1];
static int32_t r604[6144];
static int32_t r605[6144];
static int32_t r606[1024];
static int32_t r607[1024];
static int32_t r608[6144];
static int32_t r609[1];
static int32_t r610[1];
static int32_t r611[1024];
static int32_t r612[1024];
static int32_t r613[1];
static int32_t r614[1024];
static int32_t r615[1024];
static int32_t r616[1024];
static int32_t r617[6144];
static int32_t r618[6144];
static int32_t r619[1024];
static int32_t r620[6144];
static int32_t r621[1024];
static int32_t r622[6144];
static int32_t r623[6144];
static int32_t r624[1024];
static int32_t r625[1024];
static uint8_t r626[1024];
static int32_t r627[1024];
static int32_t r628[1024];
static int32_t r629[1];
static int32_t r630[1024];
static int32_t r631[1024];
static int32_t r632[6144];
static int32_t r633[1024];
static int32_t r634[1024];
static int32_t r635[6144];
static int32_t r636[1];
static int32_t r637[1];
static int32_t r638[1024];
static int32_t r639[1024];
static int32_t r640[1];
static int32_t r641[1024];
static int32_t r642[1024];
static int32_t r643[1024];
static int32_t r644[6144];
static int32_t r645[6144];
static int32_t r646[1024];
static int32_t r647[6144];
static int32_t r648[1024];
static int32_t r649[6144];
static int32_t r650[6144];
static int32_t r651[1024];
static int32_t r652[1024];
static uint8_t r653[1024];
static int32_t r654[1024];
static int32_t r655[1024];
static int32_t r656[1];
static int32_t r657[1024];
static int32_t r658[1024];
static int32_t r659[1024];
static int32_t r660[4096];
static int32_t r661[4096];
static int32_t r662[4096];
static int32_t r663[4000];
static int32_t r664[4000];
static int32_t r665[4000];
static int32_t r666[4000];
static int32_t r667[4000];
static int32_t r668[1];
static int32_t r669[4000];
static int32_t r670[1];
static int32_t r671[4000];
static int32_t r672[2000];
static int32_t r673[2000];
static int32_t r674[2000];
static int32_t r675[2000];
static int32_t r676[2000];
static int32_t r677[2000];
static int32_t r678[80];
static int32_t r679[80];
static int32_t r680[80];
static int32_t r681[1];
static int32_t r682[2015];
static int32_t r683[1];
static int32_t r684[2063];
static int32_t r685[1024];
static int32_t r686[1024];
static int32_t r687[16];
static int32_t r688[16];
static int32_t r689[16384];
static int32_t r690[2];
static int32_t r691[2];
static int32_t r692[2063];
static int32_t r693[16384];
static int32_t r694[80];
static int32_t r695[1];
static int32_t r696[1];
static int32_t r697[1];
static uint8_t r698[1];
static const int32_t *const r699 = rom25_lit;
static int32_t r700[1];
static int32_t r701[1];
static int32_t r702[1039];
static uint8_t r703[16384];
static int32_t r704[16384];
static int32_t r705[16384];
static int32_t r706[16384];
static int32_t r707[16384];
static int32_t r708[16384];
static int32_t r709[81920];
static int32_t r710[1];
static int32_t r711[81920];
static int32_t r712[1];
static int32_t r713[81920];
static int32_t r714[81920];
static int32_t r715[1];
static int32_t r716[81920];
static int32_t r717[1];
static int32_t r718[81920];
static int32_t r719[81920];
static int32_t r720[5120];
static int32_t r721[5120];
static int32_t r722[81920];
static int32_t r723[1];
static int32_t r724[1];
static int32_t r725[5120];
static int32_t r726[5120];
static int32_t r727[1];
static int32_t r728[5120];
static int32_t r729[5120];
static int32_t r730[5120];
static int32_t r731[81920];
static int32_t r732[81920];
static int32_t r733[5120];
static int32_t r734[81920];
static int32_t r735[5120];
static int32_t r736[81920];
static int32_t r737[81920];
static int32_t r738[5120];
static int32_t r739[5120];
static uint8_t r740[5120];
static int32_t r741[5120];
static int32_t r742[5120];
static int32_t r743[1];
static int32_t r744[5120];
static int32_t r745[5120];
static int32_t r746[81920];
static int32_t r747[5120];
static int32_t r748[5120];
static int32_t r749[81920];
static int32_t r750[1];
static int32_t r751[1];
static int32_t r752[5120];
static int32_t r753[5120];
static int32_t r754[1];
static int32_t r755[5120];
static int32_t r756[5120];
static int32_t r757[5120];
static int32_t r758[81920];
static int32_t r759[81920];
static int32_t r760[5120];
static int32_t r761[81920];
static int32_t r762[5120];
static int32_t r763[81920];
static int32_t r764[81920];
static int32_t r765[5120];
static int32_t r766[5120];
static uint8_t r767[5120];
static int32_t r768[5120];
static int32_t r769[5120];
static int32_t r770[1];
static int32_t r771[5120];
static int32_t r772[5120];
static int32_t r773[5120];
static int32_t r774[10240];
static int32_t r775[10240];
static int32_t r776[10240];
static int32_t r777[10000];
static int32_t r778[10000];
static int32_t r779[10000];
static int32_t r780[5];
static const int32_t *const r781 = rom26_lit;
static int32_t r782[5];
static int32_t r783[2000];
static int32_t r784[6];
static int32_t r785[6];
static int32_t r786[6];
static int32_t r787[1];
static int32_t r788[2005];
static int32_t r789[1];
static int32_t r790[2053];
static int32_t r791[1024];
static int32_t r792[1024];
static int32_t r793[6];
static int32_t r794[6];
static int32_t r795[6144];
static int32_t r796[2];
static int32_t r797[2];
static int32_t r798[2053];
static int32_t r799[6144];
static int32_t r800[6];
static int32_t r801[1];
static int32_t r802[1];
static int32_t r803[1];
static uint8_t r804[1];
static const int32_t *const r805 = rom27_lit;
static int32_t r806[1];
static int32_t r807[1];
static int32_t r808[1029];
static uint8_t r809[6144];
static int32_t r810[6144];
static int32_t r811[6144];
static int32_t r812[6144];
static int32_t r813[6144];
static int32_t r814[6144];
static int32_t r815[6144];
static int32_t r816[1];
static int32_t r817[6144];
static int32_t r818[1];
static int32_t r819[6144];
static int32_t r820[6144];
static int32_t r821[1];
static int32_t r822[6144];
static int32_t r823[1];
static int32_t r824[6144];
static int32_t r825[6144];
static int32_t r826[1024];
static int32_t r827[1024];
static int32_t r828[6144];
static int32_t r829[1];
static int32_t r830[1];
static int32_t r831[1024];
static int32_t r832[1024];
static int32_t r833[1];
static int32_t r834[1024];
static int32_t r835[1024];
static int32_t r836[1024];
static int32_t r837[6144];
static int32_t r838[6144];
static int32_t r839[1024];
static int32_t r840[6144];
static int32_t r841[1024];
static int32_t r842[6144];
static int32_t r843[6144];
static int32_t r844[1024];
static int32_t r845[1024];
static uint8_t r846[1024];
static int32_t r847[1024];
static int32_t r848[1024];
static int32_t r849[1];
static int32_t r850[1024];
static int32_t r851[1024];
static int32_t r852[6144];
static int32_t r853[1024];
static int32_t r854[1024];
static int32_t r855[6144];
static int32_t r856[1];
static int32_t r857[1];
static int32_t r858[1024];
static int32_t r859[1024];
static int32_t r860[1];
static int32_t r861[1024];
static int32_t r862[1024];
static int32_t r863[1024];
static int32_t r864[6144];
static int32_t r865[6144];
static int32_t r866[1024];
static int32_t r867[6144];
static int32_t r868[1024];
static int32_t r869[6144];
static int32_t r870[6144];
static int32_t r871[1024];
static int32_t r872[1024];
static uint8_t r873[1024];
static int32_t r874[1024];
static int32_t r875[1024];
static int32_t r876[1];
static int32_t r877[1024];
static int32_t r878[1024];
static int32_t r879[1024];
static int32_t r880[2048];
static int32_t r881[2048];
static int32_t r882[2048];
static int32_t r883[2000];
static int32_t r884[2000];
static int32_t r885[2000];
static int32_t r886[2000];
static int32_t r887[2000];
static int32_t r888[1];
static int32_t r889[2000];
static int32_t r890[1];
static int32_t r891[2000];
static int32_t r892[1000];
static int32_t r893[1000];
static int32_t r894[1000];
static int32_t r895[1000];
static int32_t r896[1000];
static int32_t r897[1000];
static int32_t r898[80];
static int32_t r899[80];
static int32_t r900[80];
static int32_t r901[1];
static int32_t r902[1015];
static int32_t r903[1000];
static int32_t r904[1000];
static int32_t r905[16];
static int32_t r906[16];
static int32_t r907[16000];
static uint8_t r908[16000];
static const int32_t *const r909 = rom28_lit;
static int32_t r910[16000];
static int32_t r911[16000];
static int32_t r912[16000];
static int32_t r913[16000];
static int32_t r914[16000];
static int32_t r915[80000];
static int32_t r916[1];
static int32_t r917[80000];
static int32_t r918[1];
static int32_t r919[80000];
static int32_t r920[80000];
static int32_t r921[1];
static int32_t r922[80000];
static int32_t r923[1];
static int32_t r924[80000];
static int32_t r925[80000];
static int32_t r926[5000];
static int32_t r927[5000];
static int32_t r928[80000];
static int32_t r929[1];
static int32_t r930[1];
static int32_t r931[5000];
static int32_t r932[5000];
static int32_t r933[1];
static int32_t r934[5000];
static int32_t r935[5000];
static int32_t r936[5000];
static int32_t r937[80000];
static int32_t r938[80000];
static int32_t r939[5000];
static int32_t r940[80000];
static int32_t r941[5000];
static int32_t r942[80000];
static int32_t r943[80000];
static int32_t r944[5000];
static int32_t r945[5000];
static uint8_t r946[5000];
static int32_t r947[5000];
static int32_t r948[5000];
static int32_t r949[1];
static int32_t r950[5000];
static int32_t r951[5000];
static int32_t r952[80000];
static int32_t r953[5000];
static int32_t r954[5000];
static int32_t r955[80000];
static int32_t r956[1];
static int32_t r957[1];
static int32_t r958[5000];
static int32_t r959[5000];
static int32_t r960[1];
static int32_t r961[5000];
static int32_t r962[5000];
static int32_t r963[5000];
static int32_t r964[80000];
static int32_t r965[80000];
static int32_t r966[5000];
static int32_t r967[80000];
static int32_t r968[5000];
static int32_t r969[80000];
static int32_t r970[80000];
static int32_t r971[5000];
static int32_t r972[5000];
static uint8_t r973[5000];
static int32_t r974[5000];
static int32_t r975[5000];
static int32_t r976[1];
static int32_t r977[5000];
static int32_t r978[5000];
static int32_t r979[5000];
static int32_t r980[5000];
static int32_t r981[5000];
static int32_t r982[5];
static const int32_t *const r983 = rom29_lit;
static int32_t r984[5];
static int32_t r985[1000];
static int32_t r986[6];
static int32_t r987[6];
static int32_t r988[6];
static int32_t r989[1];
static int32_t r990[1005];
static int32_t r991[1000];
static int32_t r992[1000];
static int32_t r993[6];
static int32_t r994[6];
static int32_t r995[6000];
static uint8_t r996[6000];
static const int32_t *const r997 = rom30_lit;
static int32_t r998[6000];
static int32_t r999[6000];
static int32_t r1000[6000];
static int32_t r1001[6000];
static int32_t r1002[6000];
static int32_t r1003[6000];
static int32_t r1004[1];
static int32_t r1005[6000];
static int32_t r1006[1];
static int32_t r1007[6000];
static int32_t r1008[6000];
static int32_t r1009[1];
static int32_t r1010[6000];
static int32_t r1011[1];
static int32_t r1012[6000];
static int32_t r1013[6000];
static int32_t r1014[1000];
static int32_t r1015[1000];
static int32_t r1016[6000];
static int32_t r1017[1];
static int32_t r1018[1];
static int32_t r1019[1000];
static int32_t r1020[1000];
static int32_t r1021[1];
static int32_t r1022[1000];
static int32_t r1023[1000];
static int32_t r1024[1000];
static int32_t r1025[6000];
static int32_t r1026[6000];
static int32_t r1027[1000];
static int32_t r1028[6000];
static int32_t r1029[1000];
static int32_t r1030[6000];
static int32_t r1031[6000];
static int32_t r1032[1000];
static int32_t r1033[1000];
static uint8_t r1034[1000];
static int32_t r1035[1000];
static int32_t r1036[1000];
static int32_t r1037[1];
static int32_t r1038[1000];
static int32_t r1039[1000];
static int32_t r1040[6000];
static int32_t r1041[1000];
static int32_t r1042[1000];
static int32_t r1043[6000];
static int32_t r1044[1];
static int32_t r1045[1];
static int32_t r1046[1000];
static int32_t r1047[1000];
static int32_t r1048[1];
static int32_t r1049[1000];
static int32_t r1050[1000];
static int32_t r1051[1000];
static int32_t r1052[6000];
static int32_t r1053[6000];
static int32_t r1054[1000];
static int32_t r1055[6000];
static int32_t r1056[1000];
static int32_t r1057[6000];
static int32_t r1058[6000];
static int32_t r1059[1000];
static int32_t r1060[1000];
static uint8_t r1061[1000];
static int32_t r1062[1000];
static int32_t r1063[1000];
static int32_t r1064[1];
static int32_t r1065[1000];
static int32_t r1066[1000];
static int32_t r1067[1000];
static int32_t r1068[1000];
static int32_t r1069[1000];
static int32_t r1070[1000];
static int32_t r1071[1000];
static int32_t r1072[1];
static int32_t r1073[1000];
static int32_t r1074[1];
static int32_t r1075[1000];
static int32_t r1076[500];
static int32_t r1077[500];
static int32_t r1078[500];
static int32_t r1079[500];
static int32_t r1080[500];
static int32_t r1081[500];
static int32_t r1082[80];
static int32_t r1083[80];
static int32_t r1084[80];
static int32_t r1085[1];
static int32_t r1086[515];
static int32_t r1087[500];
static int32_t r1088[500];
static int32_t r1089[16];
static int32_t r1090[16];
static int32_t r1091[8000];
static uint8_t r1092[8000];
static const int32_t *const r1093 = rom31_lit;
static int32_t r1094[8000];
static int32_t r1095[8000];
static int32_t r1096[8000];
static int32_t r1097[8000];
static int32_t r1098[8000];
static int32_t r1099[40000];
static int32_t r1100[1];
static int32_t r1101[40000];
static int32_t r1102[1];
static int32_t r1103[40000];
static int32_t r1104[40000];
static int32_t r1105[1];
static int32_t r1106[40000];
static int32_t r1107[1];
static int32_t r1108[40000];
static int32_t r1109[40000];
static int32_t r1110[2500];
static int32_t r1111[2500];
static int32_t r1112[40000];
static int32_t r1113[1];
static int32_t r1114[1];
static int32_t r1115[2500];
static int32_t r1116[2500];
static int32_t r1117[1];
static int32_t r1118[2500];
static int32_t r1119[2500];
static int32_t r1120[2500];
static int32_t r1121[40000];
static int32_t r1122[40000];
static int32_t r1123[2500];
static int32_t r1124[40000];
static int32_t r1125[2500];
static int32_t r1126[40000];
static int32_t r1127[40000];
static int32_t r1128[2500];
static int32_t r1129[2500];
static uint8_t r1130[2500];
static int32_t r1131[2500];
static int32_t r1132[2500];
static int32_t r1133[1];
static int32_t r1134[2500];
static int32_t r1135[2500];
static int32_t r1136[40000];
static int32_t r1137[2500];
static int32_t r1138[2500];
static int32_t r1139[40000];
static int32_t r1140[1];
static int32_t r1141[1];
static int32_t r1142[2500];
static int32_t r1143[2500];
static int32_t r1144[1];
static int32_t r1145[2500];
static int32_t r1146[2500];
static int32_t r1147[2500];
static int32_t r1148[40000];
static int32_t r1149[40000];
static int32_t r1150[2500];
static int32_t r1151[40000];
static int32_t r1152[2500];
static int32_t r1153[40000];
static int32_t r1154[40000];
static int32_t r1155[2500];
static int32_t r1156[2500];
static uint8_t r1157[2500];
static int32_t r1158[2500];
static int32_t r1159[2500];
static int32_t r1160[1];
static int32_t r1161[2500];
static int32_t r1162[2500];
static int32_t r1163[2500];
static int32_t r1164[2500];
static int32_t r1165[2500];
static int32_t r1166[5];
static const int32_t *const r1167 = rom32_lit;
static int32_t r1168[5];
static int32_t r1169[30];
static int32_t r1170[30];
static int32_t r1171[30];
static int32_t r1172[30];
static int32_t r1173[30];
static uint8_t r1174[30];
static int32_t r1175[30];
static int32_t r1176[30];
static int32_t r1177[30];
static int32_t r1178[30];
static int32_t r1179[30];
static int32_t r1180[30];
static int32_t r1181[30];
static uint8_t r1182[30];
static int32_t r1183[30];
static int32_t r1184[30];
static uint8_t r1185[30];
static int32_t r1186[30];
static int32_t r1187[30];
static int32_t r1188[30];
static int32_t r1189[30];
static int32_t r1190[30];
static int32_t r1191[30];
static int32_t r1192[30];
static uint8_t r1193[30];
static int32_t r1194[30];
static int32_t r1195[30];
static uint8_t r1196[30];
static int32_t r1197[30];
static uint8_t r1198[30];
static int32_t r1199[30];
static uint8_t r1200[30];
static int32_t r1201[30];
static uint8_t r1202[30];
static int32_t r1203[30];
static int32_t r1204[1];
static int32_t r1205[30];
static int32_t r1206[1];
static int32_t r1207[30];
static int32_t r1208[30];
static int32_t r1209[30];
static int32_t r1210[30];
static int32_t r1211[30];
static int32_t r1212[300];
static int32_t r1213[300];
static int32_t r1214[300];
static int32_t r1215[300];
static int32_t r1216[1];
static int32_t r1217[300];
static int32_t r1218[1];
static int32_t r1219[300];
static int32_t r1220[300];
static int32_t r1221[300];
static int32_t r1222[1];
static int32_t r1223[300];
static int32_t r1224[1];
static int32_t r1225[300];
static int32_t r1226[600];
static int32_t r1227[10];
static int32_t r1228[10];
static int32_t r1229[610];
static int32_t r1230[610];
static int32_t r1231[10];
static const int32_t *const r1232 = rom33_lit;
static int32_t r1233[10];
static int32_t r1234[610];
static int32_t r1235[1];
static int32_t r1236[1];
static int32_t r1237[10];
static int32_t r1238[10];
static int32_t r1239[1];
static int32_t r1240[10];
static int32_t r1241[10];
static int32_t r1242[10];
static int32_t r1243[610];
static int32_t r1244[610];
static int32_t r1245[10];
static uint8_t r1246[10];
static int32_t r1247[10];
static int32_t r1248[10];
static int32_t r1249[1];
static int32_t r1250[10];
static int32_t r1251[10];
static int32_t r1252[300];
static int32_t r1253[300];
static int32_t r1254[1];
static int32_t r1255[300];
static int32_t r1256[1];
static int32_t r1257[300];
static int32_t r1258[300];
static int32_t r1259[300];
static int32_t r1260[1];
static int32_t r1261[300];
static int32_t r1262[1];
static int32_t r1263[300];
static int32_t r1264[600];
static int32_t r1265[10];
static int32_t r1266[10];
static int32_t r1267[610];
static int32_t r1268[610];
static int32_t r1269[10];
static int32_t r1270[10];
static int32_t r1271[610];
static int32_t r1272[1];
static int32_t r1273[1];
static int32_t r1274[10];
static int32_t r1275[10];
static int32_t r1276[1];
static int32_t r1277[10];
static int32_t r1278[10];
static int32_t r1279[10];
static int32_t r1280[610];
static int32_t r1281[610];
static int32_t r1282[10];
static uint8_t r1283[10];
static int32_t r1284[10];
static int32_t r1285[10];
static int32_t r1286[1];
static int32_t r1287[10];
static int32_t r1288[10];
static int32_t r1289[10];
static int32_t r1290[10];
static int32_t r1291[20];
static int32_t r1292[10];
static const int32_t *const r1293 = rom34_lit;
static int32_t r1294[10];
static int32_t r1295[20];
static int32_t r1296[1];
static int32_t r1297[1];
static int32_t r1298[10];
static int32_t r1299[10];
static int32_t r1300[1];
static int32_t r1301[10];
static int32_t r1302[10];
static int32_t r1303[10];
static int32_t r1304[20];
static int32_t r1305[20];
static int32_t r1306[10];
static uint8_t r1307[10];
static int32_t r1308[10];
static int32_t r1309[10];
static int32_t r1310[1];
static int32_t r1311[10];
static int32_t r1312[10];
static int32_t r1313[10];
static int32_t r1314[10];
static int32_t r1315[10];
static int32_t r1316[10];
static int32_t r1317[10];

static void program_run(void) {
    /* shl [shift_left] -> r10 */
    for (long i1 = 0; i1 < 16000; ++i1) {
        r10[i1] = shl32(r0[i1], 1);
    }
    /* mov [device_put] -> r11 */
    memcpy(r11, r1, sizeof(int32_t) * 80);
    /* rev [rev] -> r12 */
    for (long i2 = 0; i2 < 80; ++i2) {
        long t4 = i2;
        long c30 = t4 / 16; t4 %= 16;
        long c31 = t4;
        r12[i2] = r11[c30 * 16 + (16 - 1 - c31) * 1];
    }
    /* reshape [reshape] -> r13 */
    memcpy(r13, r12, sizeof(int32_t) * 80);
    /* convert [convert_element_type] -> r15 */
    for (long i5 = 0; i5 < 1; ++i5) {
        r15[i5] = (int32_t)r14[0];
    }
    /* pad [pad] -> r16 */
    for (long i6 = 0; i6 < 16015; ++i6) {
        r16[i6] = r15[0];
    }
    for (long i7 = 0; i7 < 16000; ++i7) {
        long t9 = i7;
        long c80 = t9 / 16000; t9 %= 16000;
        long c81 = t9;
        long d10 = 0 + c80 * 1;
        long d11 = 15 + c81 * 1;
        if (d10 >= 0 && d10 < 1 && d11 >= 0 && d11 < 16015) r16[d10 * 16015 + d11 * 1] = r10[i7];
    }
    /* convert [convert_element_type] -> r17 */
    for (long i12 = 0; i12 < 1; ++i12) {
        r17[i12] = (int32_t)r14[0];
    }
    /* pad [pad] -> r18 */
    for (long i13 = 0; i13 < 16399; ++i13) {
        r18[i13] = r17[0];
    }
    for (long i14 = 0; i14 < 16015; ++i14) {
        long t16 = i14;
        long c150 = t16 / 16015; t16 %= 16015;
        long c151 = t16;
        long d17 = 0 + c150 * 1;
        long d18 = 0 + c151 * 1;
        if (d17 >= 0 && d17 < 1 && d18 >= 0 && d18 < 16399) r18[d17 * 16399 + d18 * 1] = r16[i14];
    }
    /* iota [iota] -> r19 */
    for (long i19 = 0; i19 < 1024; ++i19) {
        long t21 = i19;
        long c200 = t21;
        r19[i19] = (int32_t)c200;
    }
    /* broadcast [broadcast_in_dim] -> r20 */
    for (long i22 = 0; i22 < 1024; ++i22) {
        long t24 = i22;
        long c230 = t24 / 1; t24 %= 1;
        long c231 = t24;
        r20[i22] = r19[c230 * 1];
    }
    /* iota [iota] -> r21 */
    for (long i25 = 0; i25 < 16; ++i25) {
        long t27 = i25;
        long c260 = t27;
        r21[i25] = (int32_t)c260;
    }
    /* broadcast [broadcast_in_dim] -> r22 */
    for (long i28 = 0; i28 < 16; ++i28) {
        long t30 = i28;
        long c290 = t30 / 16; t30 %= 16;
        long c291 = t30;
        r22[i28] = r21[c291 * 1];
    }
    /* add [add] -> r23 */
    for (long i31 = 0; i31 < 16384; ++i31) {
        long t33 = i31;
        long c320 = t33 / 16; t33 %= 16;
        long c321 = t33;
        r23[i31] = add32(r20[c320 * 1], r22[c321 * 1]);
    }
    /* iota [iota] -> r24 */
    for (long i34 = 0; i34 < 16; ++i34) {
        long t36 = i34;
        long c350 = t36;
        r24[i34] = (int32_t)c350;
    }
    /* shl [mul] -> r25 */
    for (long i37 = 0; i37 < 16; ++i37) {
        r25[i37] = shl32(r24[i37], 10);
    }
    /* loop [scan] -> r113 */
    memcpy(r26, r18, sizeof(int32_t) * 16399);
    memcpy(r27, r23, sizeof(int32_t) * 16384);
    memcpy(r28, r13, sizeof(int32_t) * 80);
    for (long t38 = 0; t38 < 16; ++t38) {
        memcpy(r29, r25 + t38 * 1, sizeof(int32_t) * 1);
        /* add [add] -> r30 */
        for (long i1039 = 0; i1039 < 1; ++i1039) {
            r30[i1039] = add32(r14[0], r9[0]);
        }
        /* select_n [select_n] -> r32 */
        for (long i1040 = 0; i1040 < 1; ++i1040) {
            r32[i1040] = r31[0] == 0 ? r14[0] : (r30[0]);
        }
        /* lt [lt] -> r33 */
        for (long i1041 = 0; i1041 < 1; ++i1041) {
            r33[i1041] = r29[0] < r14[0] ? 1 : 0;
        }
        /* add [add] -> r35 */
        for (long i1042 = 0; i1042 < 1; ++i1042) {
            r35[i1042] = add32(r29[0], r34[0]);
        }
        /* select_n [select_n] -> r36 */
        for (long i1043 = 0; i1043 < 1; ++i1043) {
            r36[i1043] = r33[0] == 0 ? r29[0] : (r35[0]);
        }
        /* dynamic_slice [dynamic_slice] -> r37 */
        long s1044 = clamp_start((long)r32[0], 1, 1);
        long s1045 = clamp_start((long)r36[0], 16399, 1039);
        {
        for (long i1046 = 0; i1046 < 1039; ++i1046) {
            long t1048 = i1046;
            long c10470 = t1048 / 1039; t1048 %= 1039;
            long c10471 = t1048;
            r37[i1046] = r26[(s1044 + c10470) * 16399 + (s1045 + c10471) * 1];
        }
        }
        /* lt [lt] -> r38 */
        for (long i1049 = 0; i1049 < 16384; ++i1049) {
            r38[i1049] = r27[i1049] < r14[0] ? 1 : 0;
        }
        /* add [add] -> r40 */
        for (long i1050 = 0; i1050 < 16384; ++i1050) {
            r40[i1050] = add32(r27[i1050], r39[0]);
        }
        /* select_n [select_n] -> r41 */
        for (long i1051 = 0; i1051 < 16384; ++i1051) {
            r41[i1051] = r38[i1051] == 0 ? r27[i1051] : (r40[i1051]);
        }
        /* broadcast [broadcast_in_dim] -> r42 */
        for (long i1052 = 0; i1052 < 16384; ++i1052) {
            long t1054 = i1052;
            long c10530 = t1054 / 16; t1054 %= 16;
            long c10531 = t1054 / 1; t1054 %= 1;
            long c10532 = t1054;
            r42[i1052] = r41[c10530 * 16 + c10531 * 1];
        }
        /* gather [gather] -> r43 */
        for (long i1055 = 0; i1055 < 16384; ++i1055) {
            long t1057 = i1055;
            long c10560 = t1057 / 16384; t1057 %= 16384;
            long c10561 = t1057 / 16; t1057 %= 16;
            long c10562 = t1057;
            long row1058 = c10561 * 16 + c10562 * 1;
            long s1059 = clamp_start((long)r42[row1058 + 0], 1039, 1);
            r43[i1055] = r37[c10560 * 1039 + s1059 * 1];
        }
        /* broadcast [broadcast_in_dim] -> r44 */
        for (long i1060 = 0; i1060 < 16384; ++i1060) {
            long t1062 = i1060;
            long c10610 = t1062 / 16384; t1062 %= 16384;
            long c10611 = t1062 / 16384; t1062 %= 16384;
            long c10612 = t1062 / 16; t1062 %= 16;
            long c10613 = t1062;
            r44[i1060] = r43[c10612 * 16 + c10613 * 1];
        }
        /* add [add] -> r45 */
        for (long i1063 = 0; i1063 < 81920; ++i1063) {
            long t1065 = i1063;
            long c10640 = t1065 / 16384; t1065 %= 16384;
            long c10641 = t1065 / 16384; t1065 %= 16384;
            long c10642 = t1065 / 16; t1065 %= 16;
            long c10643 = t1065;
            r45[i1063] = add32(r28[c10640 * 16 + c10643 * 1], r44[c10642 * 16 + c10643 * 1]);
        }
        /* convert [convert_element_type] -> r48 */
        for (long i1066 = 0; i1066 < 1; ++i1066) {
            r48[i1066] = (int32_t)r46[0];
        }
        /* max [max] -> r49 */
        for (long i1067 = 0; i1067 < 81920; ++i1067) {
            r49[i1067] = max32(r48[0], r45[i1067]);
        }
        /* convert [convert_element_type] -> r50 */
        for (long i1068 = 0; i1068 < 1; ++i1068) {
            r50[i1068] = (int32_t)r47[0];
        }
        /* min [min] -> r51 */
        for (long i1069 = 0; i1069 < 81920; ++i1069) {
            r51[i1069] = min32(r50[0], r49[i1069]);
        }
        /* sub [sub] -> r52 */
        for (long i1070 = 0; i1070 < 81920; ++i1070) {
            long t1072 = i1070;
            long c10710 = t1072 / 16384; t1072 %= 16384;
            long c10711 = t1072 / 16384; t1072 %= 16384;
            long c10712 = t1072 / 16; t1072 %= 16;
            long c10713 = t1072;
            r52[i1070] = sub32(r28[c10710 * 16 + c10713 * 1], r44[c10712 * 16 + c10713 * 1]);
        }
        /* convert [convert_element_type] -> r53 */
        for (long i1073 = 0; i1073 < 1; ++i1073) {
            r53[i1073] = (int32_t)r46[0];
        }
        /* max [max] -> r54 */
        for (long i1074 = 0; i1074 < 81920; ++i1074) {
            r54[i1074] = max32(r53[0], r52[i1074]);
        }
        /* convert [convert_element_type] -> r55 */
        for (long i1075 = 0; i1075 < 1; ++i1075) {
            r55[i1075] = (int32_t)r47[0];
        }
        /* min [min] -> r56 */
        for (long i1076 = 0; i1076 < 81920; ++i1076) {
            r56[i1076] = min32(r55[0], r54[i1076]);
        }
        /* abs [abs] -> r57 */
        for (long i1077 = 0; i1077 < 81920; ++i1077) {
            r57[i1077] = abs32(r51[i1077]);
        }
        /* reduce_max [reduce_max] -> r58 */
        for (long i1078 = 0; i1078 < 5120; ++i1078) {
            r58[i1078] = (-2147483647 - 1);
        }
        for (long i1079 = 0; i1079 < 81920; ++i1079) {
            long t1081 = i1079;
            long c10800 = t1081 / 16384; t1081 %= 16384;
            long c10801 = t1081 / 16384; t1081 %= 16384;
            long c10802 = t1081 / 16; t1081 %= 16;
            long c10803 = t1081;
            r58[c10800 * 1024 + c10801 * 1024 + c10802 * 1] = max32(r58[c10800 * 1024 + c10801 * 1024 + c10802 * 1], r57[i1079]);
        }
        /* sub [sub] -> r60 */
        for (long i1082 = 0; i1082 < 5120; ++i1082) {
            r60[i1082] = sub32(r58[i1082], r59[0]);
        }
        /* loop [scan] -> r82 */
        memcpy(r61, r51, sizeof(int32_t) * 81920);
        memcpy(r62, r59, sizeof(int32_t) * 1);
        memcpy(r63, r14, sizeof(int32_t) * 1);
        memcpy(r64, r60, sizeof(int32_t) * 5120);
        memcpy(r65, r58, sizeof(int32_t) * 5120);
        for (long t1083 = 0; t1083 < 12; ++t1083) {
            /* add [add] -> r66 */
            for (long i2084 = 0; i2084 < 1; ++i2084) {
                r66[i2084] = add32(r63[0], r9[0]);
            }
            /* add [add] -> r67 */
            for (long i2085 = 0; i2085 < 5120; ++i2085) {
                r67[i2085] = add32(r64[i2085], r65[i2085]);
            }
            /* shra [shift_right_arithmetic] -> r68 */
            for (long i2086 = 0; i2086 < 5120; ++i2086) {
                r68[i2086] = asr32(r67[i2086], 1);
            }
            /* broadcast [broadcast_in_dim] -> r69 */
            for (long i2087 = 0; i2087 < 5120; ++i2087) {
                long t2089 = i2087;
                long c20880 = t2089 / 1024; t2089 %= 1024;
                long c20881 = t2089 / 1024; t2089 %= 1024;
                long c20882 = t2089 / 1; t2089 %= 1;
                long c20883 = t2089;
                r69[i2087] = r68[c20880 * 1024 + c20882 * 1];
            }
            /* sub [sub] -> r70 */
            for (long i2090 = 0; i2090 < 81920; ++i2090) {
                long t2092 = i2090;
                long c20910 = t2092 / 16384; t2092 %= 16384;
                long c20911 = t2092 / 16384; t2092 %= 16384;
                long c20912 = t2092 / 16; t2092 %= 16;
                long c20913 = t2092;
                r70[i2090] = sub32(r61[c20910 * 16384 + c20912 * 16 + c20913 * 1], r69[c20910 * 1024 + c20912 * 1]);
            }
            /* max [max] -> r71 */
            for (long i2093 = 0; i2093 < 81920; ++i2093) {
                r71[i2093] = max32(r70[i2093], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r72 */
            for (long i2094 = 0; i2094 < 5120; ++i2094) {
                r72[i2094] = 0;
            }
            for (long i2095 = 0; i2095 < 81920; ++i2095) {
                long t2097 = i2095;
                long c20960 = t2097 / 16384; t2097 %= 16384;
                long c20961 = t2097 / 16384; t2097 %= 16384;
                long c20962 = t2097 / 16; t2097 %= 16;
                long c20963 = t2097;
                r72[c20960 * 1024 + c20961 * 1024 + c20962 * 1] = add32(r72[c20960 * 1024 + c20961 * 1024 + c20962 * 1], r71[i2095]);
            }
            /* neg [neg] -> r73 */
            for (long i2098 = 0; i2098 < 81920; ++i2098) {
                r73[i2098] = neg32(r61[i2098]);
            }
            /* broadcast [broadcast_in_dim] -> r74 */
            for (long i2099 = 0; i2099 < 5120; ++i2099) {
                long t2101 = i2099;
                long c21000 = t2101 / 1024; t2101 %= 1024;
                long c21001 = t2101 / 1024; t2101 %= 1024;
                long c21002 = t2101 / 1; t2101 %= 1;
                long c21003 = t2101;
                r74[i2099] = r68[c21000 * 1024 + c21002 * 1];
            }
            /* sub [sub] -> r75 */
            for (long i2102 = 0; i2102 < 81920; ++i2102) {
                long t2104 = i2102;
                long c21030 = t2104 / 16384; t2104 %= 16384;
                long c21031 = t2104 / 16384; t2104 %= 16384;
                long c21032 = t2104 / 16; t2104 %= 16;
                long c21033 = t2104;
                r75[i2102] = sub32(r73[c21030 * 16384 + c21032 * 16 + c21033 * 1], r74[c21030 * 1024 + c21032 * 1]);
            }
            /* max [max] -> r76 */
            for (long i2105 = 0; i2105 < 81920; ++i2105) {
                r76[i2105] = max32(r75[i2105], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r77 */
            for (long i2106 = 0; i2106 < 5120; ++i2106) {
                r77[i2106] = 0;
            }
            for (long i2107 = 0; i2107 < 81920; ++i2107) {
                long t2109 = i2107;
                long c21080 = t2109 / 16384; t2109 %= 16384;
                long c21081 = t2109 / 16384; t2109 %= 16384;
                long c21082 = t2109 / 16; t2109 %= 16;
                long c21083 = t2109;
                r77[c21080 * 1024 + c21081 * 1024 + c21082 * 1] = add32(r77[c21080 * 1024 + c21081 * 1024 + c21082 * 1], r76[i2107]);
            }
            /* add [add] -> r78 */
            for (long i2110 = 0; i2110 < 5120; ++i2110) {
                r78[i2110] = add32(r72[i2110], r77[i2110]);
            }
            /* gt [gt] -> r79 */
            for (long i2111 = 0; i2111 < 5120; ++i2111) {
                r79[i2111] = r78[i2111] > r62[0] ? 1 : 0;
            }
            /* select_n [select_n] -> r80 */
            for (long i2112 = 0; i2112 < 5120; ++i2112) {
                r80[i2112] = r79[i2112] == 0 ? r64[i2112] : (r68[i2112]);
            }
            /* select_n [select_n] -> r81 */
            for (long i2113 = 0; i2113 < 5120; ++i2113) {
                r81[i2113] = r79[i2113] == 0 ? r68[i2113] : (r65[i2113]);
            }
            memcpy(r63, r66, sizeof(int32_t) * 1);
            memcpy(r64, r80, sizeof(int32_t) * 5120);
            memcpy(r65, r81, sizeof(int32_t) * 5120);
        }
        memcpy(r82, r63, sizeof(int32_t) * 1);
        memcpy(r83, r64, sizeof(int32_t) * 5120);
        memcpy(r84, r65, sizeof(int32_t) * 5120);
        /* abs [abs] -> r85 */
        for (long i2114 = 0; i2114 < 81920; ++i2114) {
            r85[i2114] = abs32(r56[i2114]);
        }
        /* reduce_max [reduce_max] -> r86 */
        for (long i2115 = 0; i2115 < 5120; ++i2115) {
            r86[i2115] = (-2147483647 - 1);
        }
        for (long i2116 = 0; i2116 < 81920; ++i2116) {
            long t2118 = i2116;
            long c21170 = t2118 / 16384; t2118 %= 16384;
            long c21171 = t2118 / 16384; t2118 %= 16384;
            long c21172 = t2118 / 16; t2118 %= 16;
            long c21173 = t2118;
            r86[c21170 * 1024 + c21171 * 1024 + c21172 * 1] = max32(r86[c21170 * 1024 + c21171 * 1024 + c21172 * 1], r85[i2116]);
        }
        /* sub [sub] -> r87 */
        for (long i2119 = 0; i2119 < 5120; ++i2119) {
            r87[i2119] = sub32(r86[i2119], r59[0]);
        }
        /* loop [scan] -> r109 */
        memcpy(r88, r56, sizeof(int32_t) * 81920);
        memcpy(r89, r59, sizeof(int32_t) * 1);
        memcpy(r90, r14, sizeof(int32_t) * 1);
        memcpy(r91, r87, sizeof(int32_t) * 5120);
        memcpy(r92, r86, sizeof(int32_t) * 5120);
        for (long t2120 = 0; t2120 < 12; ++t2120) {
            /* add [add] -> r93 */
            for (long i3121 = 0; i3121 < 1; ++i3121) {
                r93[i3121] = add32(r90[0], r9[0]);
            }
            /* add [add] -> r94 */
            for (long i3122 = 0; i3122 < 5120; ++i3122) {
                r94[i3122] = add32(r91[i3122], r92[i3122]);
            }
            /* shra [shift_right_arithmetic] -> r95 */
            for (long i3123 = 0; i3123 < 5120; ++i3123) {
                r95[i3123] = asr32(r94[i3123], 1);
            }
            /* broadcast [broadcast_in_dim] -> r96 */
            for (long i3124 = 0; i3124 < 5120; ++i3124) {
                long t3126 = i3124;
                long c31250 = t3126 / 1024; t3126 %= 1024;
                long c31251 = t3126 / 1024; t3126 %= 1024;
                long c31252 = t3126 / 1; t3126 %= 1;
                long c31253 = t3126;
                r96[i3124] = r95[c31250 * 1024 + c31252 * 1];
            }
            /* sub [sub] -> r97 */
            for (long i3127 = 0; i3127 < 81920; ++i3127) {
                long t3129 = i3127;
                long c31280 = t3129 / 16384; t3129 %= 16384;
                long c31281 = t3129 / 16384; t3129 %= 16384;
                long c31282 = t3129 / 16; t3129 %= 16;
                long c31283 = t3129;
                r97[i3127] = sub32(r88[c31280 * 16384 + c31282 * 16 + c31283 * 1], r96[c31280 * 1024 + c31282 * 1]);
            }
            /* max [max] -> r98 */
            for (long i3130 = 0; i3130 < 81920; ++i3130) {
                r98[i3130] = max32(r97[i3130], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r99 */
            for (long i3131 = 0; i3131 < 5120; ++i3131) {
                r99[i3131] = 0;
            }
            for (long i3132 = 0; i3132 < 81920; ++i3132) {
                long t3134 = i3132;
                long c31330 = t3134 / 16384; t3134 %= 16384;
                long c31331 = t3134 / 16384; t3134 %= 16384;
                long c31332 = t3134 / 16; t3134 %= 16;
                long c31333 = t3134;
                r99[c31330 * 1024 + c31331 * 1024 + c31332 * 1] = add32(r99[c31330 * 1024 + c31331 * 1024 + c31332 * 1], r98[i3132]);
            }
            /* neg [neg] -> r100 */
            for (long i3135 = 0; i3135 < 81920; ++i3135) {
                r100[i3135] = neg32(r88[i3135]);
            }
            /* broadcast [broadcast_in_dim] -> r101 */
            for (long i3136 = 0; i3136 < 5120; ++i3136) {
                long t3138 = i3136;
                long c31370 = t3138 / 1024; t3138 %= 1024;
                long c31371 = t3138 / 1024; t3138 %= 1024;
                long c31372 = t3138 / 1; t3138 %= 1;
                long c31373 = t3138;
                r101[i3136] = r95[c31370 * 1024 + c31372 * 1];
            }
            /* sub [sub] -> r102 */
            for (long i3139 = 0; i3139 < 81920; ++i3139) {
                long t3141 = i3139;
                long c31400 = t3141 / 16384; t3141 %= 16384;
                long c31401 = t3141 / 16384; t3141 %= 16384;
                long c31402 = t3141 / 16; t3141 %= 16;
                long c31403 = t3141;
                r102[i3139] = sub32(r100[c31400 * 16384 + c31402 * 16 + c31403 * 1], r101[c31400 * 1024 + c31402 * 1]);
            }
            /* max [max] -> r103 */
            for (long i3142 = 0; i3142 < 81920; ++i3142) {
                r103[i3142] = max32(r102[i3142], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r104 */
            for (long i3143 = 0; i3143 < 5120; ++i3143) {
                r104[i3143] = 0;
            }
            for (long i3144 = 0; i3144 < 81920; ++i3144) {
                long t3146 = i3144;
                long c31450 = t3146 / 16384; t3146 %= 16384;
                long c31451 = t3146 / 16384; t3146 %= 16384;
                long c31452 = t3146 / 16; t3146 %= 16;
                long c31453 = t3146;
                r104[c31450 * 1024 + c31451 * 1024 + c31452 * 1] = add32(r104[c31450 * 1024 + c31451 * 1024 + c31452 * 1], r103[i3144]);
            }
            /* add [add] -> r105 */
            for (long i3147 = 0; i3147 < 5120; ++i3147) {
                r105[i3147] = add32(r99[i3147], r104[i3147]);
            }
            /* gt [gt] -> r106 */
            for (long i3148 = 0; i3148 < 5120; ++i3148) {
                r106[i3148] = r105[i3148] > r89[0] ? 1 : 0;
            }
            /* select_n [select_n] -> r107 */
            for (long i3149 = 0; i3149 < 5120; ++i3149) {
                r107[i3149] = r106[i3149] == 0 ? r91[i3149] : (r95[i3149]);
            }
            /* select_n [select_n] -> r108 */
            for (long i3150 = 0; i3150 < 5120; ++i3150) {
                r108[i3150] = r106[i3150] == 0 ? r95[i3150] : (r92[i3150]);
            }
            memcpy(r90, r93, sizeof(int32_t) * 1);
            memcpy(r91, r107, sizeof(int32_t) * 5120);
            memcpy(r92, r108, sizeof(int32_t) * 5120);
        }
        memcpy(r109, r90, sizeof(int32_t) * 1);
        memcpy(r110, r91, sizeof(int32_t) * 5120);
        memcpy(r111, r92, sizeof(int32_t) * 5120);
        /* sub [sub] -> r112 */
        for (long i3151 = 0; i3151 < 5120; ++i3151) {
            r112[i3151] = sub32(r84[i3151], r111[i3151]);
        }
        memcpy(r113 + t38 * 5120, r112, sizeof(int32_t) * 5120);
    }
    /* transpose [transpose] -> r114 */
    for (long i3152 = 0; i3152 < 81920; ++i3152) {
        long t3154 = i3152;
        long c31530 = t3154 / 16384; t3154 %= 16384;
        long c31531 = t3154 / 16384; t3154 %= 16384;
        long c31532 = t3154 / 1024; t3154 %= 1024;
        long c31533 = t3154;
        r114[i3152] = r113[c31530 * 1024 + c31531 * 1024 + c31532 * 5120 + c31533 * 1];
    }
    /* reshape [reshape] -> r115 */
    memcpy(r115, r114, sizeof(int32_t) * 81920);
    /* slice [slice] -> r116 */
    for (long i3155 = 0; i3155 < 80000; ++i3155) {
        long t3157 = i3155;
        long c31560 = t3157 / 16000; t3157 %= 16000;
        long c31561 = t3157 / 16000; t3157 %= 16000;
        long c31562 = t3157;
        r116[i3155] = r115[(0 + c31560 * 1) * 16384 + (0 + c31561 * 1) * 16384 + (0 + c31562 * 1) * 1];
    }
    /* transpose [transpose] -> r117 */
    for (long i3158 = 0; i3158 < 80000; ++i3158) {
        long t3160 = i3158;
        long c31590 = t3160 / 80000; t3160 %= 80000;
        long c31591 = t3160 / 16000; t3160 %= 16000;
        long c31592 = t3160;
        r117[i3158] = r116[c31590 * 16000 + c31591 * 16000 + c31592 * 1];
    }
    /* max [max] -> r118 */
    for (long i3161 = 0; i3161 < 80000; ++i3161) {
        r118[i3161] = max32(r117[i3161], r14[0]);
    }
    /* reduce_sum [reduce_sum] -> r119 */
    for (long i3162 = 0; i3162 < 5; ++i3162) {
        r119[i3162] = 0;
    }
    for (long i3163 = 0; i3163 < 80000; ++i3163) {
        long t3165 = i3163;
        long c31640 = t3165 / 80000; t3165 %= 80000;
        long c31641 = t3165 / 16000; t3165 %= 16000;
        long c31642 = t3165;
        r119[c31640 * 5 + c31641 * 1] = add32(r119[c31640 * 5 + c31641 * 1], r118[i3163]);
    }
    /* shl [shift_left] -> r120 */
    for (long i3166 = 0; i3166 < 5; ++i3166) {
        r120[i3166] = shl32(r119[i3166], 0);
    }
    /* shl [shift_left] -> r121 */
    for (long i3167 = 0; i3167 < 16000; ++i3167) {
        r121[i3167] = shl32(r0[i3167], 1);
    }
    /* mov [device_put] -> r122 */
    memcpy(r122, r2, sizeof(int32_t) * 6);
    /* rev [rev] -> r123 */
    for (long i3168 = 0; i3168 < 6; ++i3168) {
        long t3170 = i3168;
        long c31690 = t3170 / 6; t3170 %= 6;
        long c31691 = t3170;
        r123[i3168] = r122[c31690 * 6 + (6 - 1 - c31691) * 1];
    }
    /* reshape [reshape] -> r124 */
    memcpy(r124, r123, sizeof(int32_t) * 6);
    /* convert [convert_element_type] -> r125 */
    for (long i3171 = 0; i3171 < 1; ++i3171) {
        r125[i3171] = (int32_t)r14[0];
    }
    /* pad [pad] -> r126 */
    for (long i3172 = 0; i3172 < 16005; ++i3172) {
        r126[i3172] = r125[0];
    }
    for (long i3173 = 0; i3173 < 16000; ++i3173) {
        long t3175 = i3173;
        long c31740 = t3175 / 16000; t3175 %= 16000;
        long c31741 = t3175;
        long d3176 = 0 + c31740 * 1;
        long d3177 = 5 + c31741 * 1;
        if (d3176 >= 0 && d3176 < 1 && d3177 >= 0 && d3177 < 16005) r126[d3176 * 16005 + d3177 * 1] = r121[i3173];
    }
    /* convert [convert_element_type] -> r127 */
    for (long i3178 = 0; i3178 < 1; ++i3178) {
        r127[i3178] = (int32_t)r14[0];
    }
    /* pad [pad] -> r128 */
    for (long i3179 = 0; i3179 < 16389; ++i3179) {
        r128[i3179] = r127[0];
    }
    for (long i3180 = 0; i3180 < 16005; ++i3180) {
        long t3182 = i3180;
        long c31810 = t3182 / 16005; t3182 %= 16005;
        long c31811 = t3182;
        long d3183 = 0 + c31810 * 1;
        long d3184 = 0 + c31811 * 1;
        if (d3183 >= 0 && d3183 < 1 && d3184 >= 0 && d3184 < 16389) r128[d3183 * 16389 + d3184 * 1] = r126[i3180];
    }
    /* iota [iota] -> r129 */
    for (long i3185 = 0; i3185 < 1024; ++i3185) {
        long t3187 = i3185;
        long c31860 = t3187;
        r129[i3185] = (int32_t)c31860;
    }
    /* broadcast [broadcast_in_dim] -> r130 */
    for (long i3188 = 0; i3188 < 1024; ++i3188) {
        long t3190 = i3188;
        long c31890 = t3190 / 1; t3190 %= 1;
        long c31891 = t3190;
        r130[i3188] = r129[c31890 * 1];
    }
    /* iota [iota] -> r131 */
    for (long i3191 = 0; i3191 < 6; ++i3191) {
        long t3193 = i3191;
        long c31920 = t3193;
        r131[i3191] = (int32_t)c31920;
    }
    /* broadcast [broadcast_in_dim] -> r132 */
    for (long i3194 = 0; i3194 < 6; ++i3194) {
        long t3196 = i3194;
        long c31950 = t3196 / 6; t3196 %= 6;
        long c31951 = t3196;
        r132[i3194] = r131[c31951 * 1];
    }
    /* add [add] -> r133 */
    for (long i3197 = 0; i3197 < 6144; ++i3197) {
        long t3199 = i3197;
        long c31980 = t3199 / 6; t3199 %= 6;
        long c31981 = t3199;
        r133[i3197] = add32(r130[c31980 * 1], r132[c31981 * 1]);
    }
    /* iota [iota] -> r134 */
    for (long i3200 = 0; i3200 < 16; ++i3200) {
        long t3202 = i3200;
        long c32010 = t3202;
        r134[i3200] = (int32_t)c32010;
    }
    /* shl [mul] -> r135 */
    for (long i3203 = 0; i3203 < 16; ++i3203) {
        r135[i3203] = shl32(r134[i3203], 10);
    }
    /* loop [scan] -> r219 */
    memcpy(r136, r128, sizeof(int32_t) * 16389);
    memcpy(r137, r133, sizeof(int32_t) * 6144);
    memcpy(r138, r124, sizeof(int32_t) * 6);
    for (long t3204 = 0; t3204 < 16; ++t3204) {
        memcpy(r139, r135 + t3204 * 1, sizeof(int32_t) * 1);
        /* add [add] -> r140 */
        for (long i4205 = 0; i4205 < 1; ++i4205) {
            r140[i4205] = add32(r14[0], r9[0]);
        }
        /* select_n [select_n] -> r141 */
        for (long i4206 = 0; i4206 < 1; ++i4206) {
            r141[i4206] = r31[0] == 0 ? r14[0] : (r140[0]);
        }
        /* lt [lt] -> r142 */
        for (long i4207 = 0; i4207 < 1; ++i4207) {
            r142[i4207] = r139[0] < r14[0] ? 1 : 0;
        }
        /* add [add] -> r144 */
        for (long i4208 = 0; i4208 < 1; ++i4208) {
            r144[i4208] = add32(r139[0], r143[0]);
        }
        /* select_n [select_n] -> r145 */
        for (long i4209 = 0; i4209 < 1; ++i4209) {
            r145[i4209] = r142[0] == 0 ? r139[0] : (r144[0]);
        }
        /* dynamic_slice [dynamic_slice] -> r146 */
        long s4210 = clamp_start((long)r141[0], 1, 1);
        long s4211 = clamp_start((long)r145[0], 16389, 1029);
        {
        for (long i4212 = 0; i4212 < 1029; ++i4212) {
            long t4214 = i4212;
            long c42130 = t4214 / 1029; t4214 %= 1029;
            long c42131 = t4214;
            r146[i4212] = r136[(s4210 + c42130) * 16389 + (s4211 + c42131) * 1];
        }
        }
        /* lt [lt] -> r147 */
        for (long i4215 = 0; i4215 < 6144; ++i4215) {
            r147[i4215] = r137[i4215] < r14[0] ? 1 : 0;
        }
        /* add [add] -> r149 */
        for (long i4216 = 0; i4216 < 6144; ++i4216) {
            r149[i4216] = add32(r137[i4216], r148[0]);
        }
        /* select_n [select_n] -> r150 */
        for (long i4217 = 0; i4217 < 6144; ++i4217) {
            r150[i4217] = r147[i4217] == 0 ? r137[i4217] : (r149[i4217]);
        }
        /* broadcast [broadcast_in_dim] -> r151 */
        for (long i4218 = 0; i4218 < 6144; ++i4218) {
            long t4220 = i4218;
            long c42190 = t4220 / 6; t4220 %= 6;
            long c42191 = t4220 / 1; t4220 %= 1;
            long c42192 = t4220;
            r151[i4218] = r150[c42190 * 6 + c42191 * 1];
        }
        /* gather [gather] -> r152 */
        for (long i4221 = 0; i4221 < 6144; ++i4221) {
            long t4223 = i4221;
            long c42220 = t4223 / 6144; t4223 %= 6144;
            long c42221 = t4223 / 6; t4223 %= 6;
            long c42222 = t4223;
            long row4224 = c42221 * 6 + c42222 * 1;
            long s4225 = clamp_start((long)r151[row4224 + 0], 1029, 1);
            r152[i4221] = r146[c42220 * 1029 + s4225 * 1];
        }
        /* broadcast [broadcast_in_dim] -> r153 */
        for (long i4226 = 0; i4226 < 6144; ++i4226) {
            long t4228 = i4226;
            long c42270 = t4228 / 6144; t4228 %= 6144;
            long c42271 = t4228 / 6144; t4228 %= 6144;
            long c42272 = t4228 / 6; t4228 %= 6;
            long c42273 = t4228;
            r153[i4226] = r152[c42272 * 6 + c42273 * 1];
        }
        /* add [add] -> r154 */
        for (long i4229 = 0; i4229 < 6144; ++i4229) {
            long t4231 = i4229;
            long c42300 = t4231 / 6144; t4231 %= 6144;
            long c42301 = t4231 / 6144; t4231 %= 6144;
            long c42302 = t4231 / 6; t4231 %= 6;
            long c42303 = t4231;
            r154[i4229] = add32(r138[c42303 * 1], r153[c42302 * 6 + c42303 * 1]);
        }
        /* convert [convert_element_type] -> r155 */
        for (long i4232 = 0; i4232 < 1; ++i4232) {
            r155[i4232] = (int32_t)r46[0];
        }
        /* max [max] -> r156 */
        for (long i4233 = 0; i4233 < 6144; ++i4233) {
            r156[i4233] = max32(r155[0], r154[i4233]);
        }
        /* convert [convert_element_type] -> r157 */
        for (long i4234 = 0; i4234 < 1; ++i4234) {
            r157[i4234] = (int32_t)r47[0];
        }
        /* min [min] -> r158 */
        for (long i4235 = 0; i4235 < 6144; ++i4235) {
            r158[i4235] = min32(r157[0], r156[i4235]);
        }
        /* sub [sub] -> r159 */
        for (long i4236 = 0; i4236 < 6144; ++i4236) {
            long t4238 = i4236;
            long c42370 = t4238 / 6144; t4238 %= 6144;
            long c42371 = t4238 / 6144; t4238 %= 6144;
            long c42372 = t4238 / 6; t4238 %= 6;
            long c42373 = t4238;
            r159[i4236] = sub32(r138[c42373 * 1], r153[c42372 * 6 + c42373 * 1]);
        }
        /* convert [convert_element_type] -> r160 */
        for (long i4239 = 0; i4239 < 1; ++i4239) {
            r160[i4239] = (int32_t)r46[0];
        }
        /* max [max] -> r161 */
        for (long i4240 = 0; i4240 < 6144; ++i4240) {
            r161[i4240] = max32(r160[0], r159[i4240]);
        }
        /* convert [convert_element_type] -> r162 */
        for (long i4241 = 0; i4241 < 1; ++i4241) {
            r162[i4241] = (int32_t)r47[0];
        }
        /* min [min] -> r163 */
        for (long i4242 = 0; i4242 < 6144; ++i4242) {
            r163[i4242] = min32(r162[0], r161[i4242]);
        }
        /* abs [abs] -> r164 */
        for (long i4243 = 0; i4243 < 6144; ++i4243) {
            r164[i4243] = abs32(r158[i4243]);
        }
        /* reduce_max [reduce_max] -> r165 */
        for (long i4244 = 0; i4244 < 1024; ++i4244) {
            r165[i4244] = (-2147483647 - 1);
        }
        for (long i4245 = 0; i4245 < 6144; ++i4245) {
            long t4247 = i4245;
            long c42460 = t4247 / 6144; t4247 %= 6144;
            long c42461 = t4247 / 6144; t4247 %= 6144;
            long c42462 = t4247 / 6; t4247 %= 6;
            long c42463 = t4247;
            r165[c42460 * 1024 + c42461 * 1024 + c42462 * 1] = max32(r165[c42460 * 1024 + c42461 * 1024 + c42462 * 1], r164[i4245]);
        }
        /* sub [sub] -> r166 */
        for (long i4248 = 0; i4248 < 1024; ++i4248) {
            r166[i4248] = sub32(r165[i4248], r59[0]);
        }
        /* loop [scan] -> r188 */
        memcpy(r167, r158, sizeof(int32_t) * 6144);
        memcpy(r168, r59, sizeof(int32_t) * 1);
        memcpy(r169, r14, sizeof(int32_t) * 1);
        memcpy(r170, r166, sizeof(int32_t) * 1024);
        memcpy(r171, r165, sizeof(int32_t) * 1024);
        for (long t4249 = 0; t4249 < 12; ++t4249) {
            /* add [add] -> r172 */
            for (long i5250 = 0; i5250 < 1; ++i5250) {
                r172[i5250] = add32(r169[0], r9[0]);
            }
            /* add [add] -> r173 */
            for (long i5251 = 0; i5251 < 1024; ++i5251) {
                r173[i5251] = add32(r170[i5251], r171[i5251]);
            }
            /* shra [shift_right_arithmetic] -> r174 */
            for (long i5252 = 0; i5252 < 1024; ++i5252) {
                r174[i5252] = asr32(r173[i5252], 1);
            }
            /* broadcast [broadcast_in_dim] -> r175 */
            for (long i5253 = 0; i5253 < 1024; ++i5253) {
                long t5255 = i5253;
                long c52540 = t5255 / 1024; t5255 %= 1024;
                long c52541 = t5255 / 1024; t5255 %= 1024;
                long c52542 = t5255 / 1; t5255 %= 1;
                long c52543 = t5255;
                r175[i5253] = r174[c52542 * 1];
            }
            /* sub [sub] -> r176 */
            for (long i5256 = 0; i5256 < 6144; ++i5256) {
                long t5258 = i5256;
                long c52570 = t5258 / 6144; t5258 %= 6144;
                long c52571 = t5258 / 6144; t5258 %= 6144;
                long c52572 = t5258 / 6; t5258 %= 6;
                long c52573 = t5258;
                r176[i5256] = sub32(r167[c52572 * 6 + c52573 * 1], r175[c52572 * 1]);
            }
            /* max [max] -> r177 */
            for (long i5259 = 0; i5259 < 6144; ++i5259) {
                r177[i5259] = max32(r176[i5259], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r178 */
            for (long i5260 = 0; i5260 < 1024; ++i5260) {
                r178[i5260] = 0;
            }
            for (long i5261 = 0; i5261 < 6144; ++i5261) {
                long t5263 = i5261;
                long c52620 = t5263 / 6144; t5263 %= 6144;
                long c52621 = t5263 / 6144; t5263 %= 6144;
                long c52622 = t5263 / 6; t5263 %= 6;
                long c52623 = t5263;
                r178[c52620 * 1024 + c52621 * 1024 + c52622 * 1] = add32(r178[c52620 * 1024 + c52621 * 1024 + c52622 * 1], r177[i5261]);
            }
            /* neg [neg] -> r179 */
            for (long i5264 = 0; i5264 < 6144; ++i5264) {
                r179[i5264] = neg32(r167[i5264]);
            }
            /* broadcast [broadcast_in_dim] -> r180 */
            for (long i5265 = 0; i5265 < 1024; ++i5265) {
                long t5267 = i5265;
                long c52660 = t5267 / 1024; t5267 %= 1024;
                long c52661 = t5267 / 1024; t5267 %= 1024;
                long c52662 = t5267 / 1; t5267 %= 1;
                long c52663 = t5267;
                r180[i5265] = r174[c52662 * 1];
            }
            /* sub [sub] -> r181 */
            for (long i5268 = 0; i5268 < 6144; ++i5268) {
                long t5270 = i5268;
                long c52690 = t5270 / 6144; t5270 %= 6144;
                long c52691 = t5270 / 6144; t5270 %= 6144;
                long c52692 = t5270 / 6; t5270 %= 6;
                long c52693 = t5270;
                r181[i5268] = sub32(r179[c52692 * 6 + c52693 * 1], r180[c52692 * 1]);
            }
            /* max [max] -> r182 */
            for (long i5271 = 0; i5271 < 6144; ++i5271) {
                r182[i5271] = max32(r181[i5271], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r183 */
            for (long i5272 = 0; i5272 < 1024; ++i5272) {
                r183[i5272] = 0;
            }
            for (long i5273 = 0; i5273 < 6144; ++i5273) {
                long t5275 = i5273;
                long c52740 = t5275 / 6144; t5275 %= 6144;
                long c52741 = t5275 / 6144; t5275 %= 6144;
                long c52742 = t5275 / 6; t5275 %= 6;
                long c52743 = t5275;
                r183[c52740 * 1024 + c52741 * 1024 + c52742 * 1] = add32(r183[c52740 * 1024 + c52741 * 1024 + c52742 * 1], r182[i5273]);
            }
            /* add [add] -> r184 */
            for (long i5276 = 0; i5276 < 1024; ++i5276) {
                r184[i5276] = add32(r178[i5276], r183[i5276]);
            }
            /* gt [gt] -> r185 */
            for (long i5277 = 0; i5277 < 1024; ++i5277) {
                r185[i5277] = r184[i5277] > r168[0] ? 1 : 0;
            }
            /* select_n [select_n] -> r186 */
            for (long i5278 = 0; i5278 < 1024; ++i5278) {
                r186[i5278] = r185[i5278] == 0 ? r170[i5278] : (r174[i5278]);
            }
            /* select_n [select_n] -> r187 */
            for (long i5279 = 0; i5279 < 1024; ++i5279) {
                r187[i5279] = r185[i5279] == 0 ? r174[i5279] : (r171[i5279]);
            }
            memcpy(r169, r172, sizeof(int32_t) * 1);
            memcpy(r170, r186, sizeof(int32_t) * 1024);
            memcpy(r171, r187, sizeof(int32_t) * 1024);
        }
        memcpy(r188, r169, sizeof(int32_t) * 1);
        memcpy(r189, r170, sizeof(int32_t) * 1024);
        memcpy(r190, r171, sizeof(int32_t) * 1024);
        /* abs [abs] -> r191 */
        for (long i5280 = 0; i5280 < 6144; ++i5280) {
            r191[i5280] = abs32(r163[i5280]);
        }
        /* reduce_max [reduce_max] -> r192 */
        for (long i5281 = 0; i5281 < 1024; ++i5281) {
            r192[i5281] = (-2147483647 - 1);
        }
        for (long i5282 = 0; i5282 < 6144; ++i5282) {
            long t5284 = i5282;
            long c52830 = t5284 / 6144; t5284 %= 6144;
            long c52831 = t5284 / 6144; t5284 %= 6144;
            long c52832 = t5284 / 6; t5284 %= 6;
            long c52833 = t5284;
            r192[c52830 * 1024 + c52831 * 1024 + c52832 * 1] = max32(r192[c52830 * 1024 + c52831 * 1024 + c52832 * 1], r191[i5282]);
        }
        /* sub [sub] -> r193 */
        for (long i5285 = 0; i5285 < 1024; ++i5285) {
            r193[i5285] = sub32(r192[i5285], r59[0]);
        }
        /* loop [scan] -> r215 */
        memcpy(r194, r163, sizeof(int32_t) * 6144);
        memcpy(r195, r59, sizeof(int32_t) * 1);
        memcpy(r196, r14, sizeof(int32_t) * 1);
        memcpy(r197, r193, sizeof(int32_t) * 1024);
        memcpy(r198, r192, sizeof(int32_t) * 1024);
        for (long t5286 = 0; t5286 < 12; ++t5286) {
            /* add [add] -> r199 */
            for (long i6287 = 0; i6287 < 1; ++i6287) {
                r199[i6287] = add32(r196[0], r9[0]);
            }
            /* add [add] -> r200 */
            for (long i6288 = 0; i6288 < 1024; ++i6288) {
                r200[i6288] = add32(r197[i6288], r198[i6288]);
            }
            /* shra [shift_right_arithmetic] -> r201 */
            for (long i6289 = 0; i6289 < 1024; ++i6289) {
                r201[i6289] = asr32(r200[i6289], 1);
            }
            /* broadcast [broadcast_in_dim] -> r202 */
            for (long i6290 = 0; i6290 < 1024; ++i6290) {
                long t6292 = i6290;
                long c62910 = t6292 / 1024; t6292 %= 1024;
                long c62911 = t6292 / 1024; t6292 %= 1024;
                long c62912 = t6292 / 1; t6292 %= 1;
                long c62913 = t6292;
                r202[i6290] = r201[c62912 * 1];
            }
            /* sub [sub] -> r203 */
            for (long i6293 = 0; i6293 < 6144; ++i6293) {
                long t6295 = i6293;
                long c62940 = t6295 / 6144; t6295 %= 6144;
                long c62941 = t6295 / 6144; t6295 %= 6144;
                long c62942 = t6295 / 6; t6295 %= 6;
                long c62943 = t6295;
                r203[i6293] = sub32(r194[c62942 * 6 + c62943 * 1], r202[c62942 * 1]);
            }
            /* max [max] -> r204 */
            for (long i6296 = 0; i6296 < 6144; ++i6296) {
                r204[i6296] = max32(r203[i6296], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r205 */
            for (long i6297 = 0; i6297 < 1024; ++i6297) {
                r205[i6297] = 0;
            }
            for (long i6298 = 0; i6298 < 6144; ++i6298) {
                long t6300 = i6298;
                long c62990 = t6300 / 6144; t6300 %= 6144;
                long c62991 = t6300 / 6144; t6300 %= 6144;
                long c62992 = t6300 / 6; t6300 %= 6;
                long c62993 = t6300;
                r205[c62990 * 1024 + c62991 * 1024 + c62992 * 1] = add32(r205[c62990 * 1024 + c62991 * 1024 + c62992 * 1], r204[i6298]);
            }
            /* neg [neg] -> r206 */
            for (long i6301 = 0; i6301 < 6144; ++i6301) {
                r206[i6301] = neg32(r194[i6301]);
            }
            /* broadcast [broadcast_in_dim] -> r207 */
            for (long i6302 = 0; i6302 < 1024; ++i6302) {
                long t6304 = i6302;
                long c63030 = t6304 / 1024; t6304 %= 1024;
                long c63031 = t6304 / 1024; t6304 %= 1024;
                long c63032 = t6304 / 1; t6304 %= 1;
                long c63033 = t6304;
                r207[i6302] = r201[c63032 * 1];
            }
            /* sub [sub] -> r208 */
            for (long i6305 = 0; i6305 < 6144; ++i6305) {
                long t6307 = i6305;
                long c63060 = t6307 / 6144; t6307 %= 6144;
                long c63061 = t6307 / 6144; t6307 %= 6144;
                long c63062 = t6307 / 6; t6307 %= 6;
                long c63063 = t6307;
                r208[i6305] = sub32(r206[c63062 * 6 + c63063 * 1], r207[c63062 * 1]);
            }
            /* max [max] -> r209 */
            for (long i6308 = 0; i6308 < 6144; ++i6308) {
                r209[i6308] = max32(r208[i6308], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r210 */
            for (long i6309 = 0; i6309 < 1024; ++i6309) {
                r210[i6309] = 0;
            }
            for (long i6310 = 0; i6310 < 6144; ++i6310) {
                long t6312 = i6310;
                long c63110 = t6312 / 6144; t6312 %= 6144;
                long c63111 = t6312 / 6144; t6312 %= 6144;
                long c63112 = t6312 / 6; t6312 %= 6;
                long c63113 = t6312;
                r210[c63110 * 1024 + c63111 * 1024 + c63112 * 1] = add32(r210[c63110 * 1024 + c63111 * 1024 + c63112 * 1], r209[i6310]);
            }
            /* add [add] -> r211 */
            for (long i6313 = 0; i6313 < 1024; ++i6313) {
                r211[i6313] = add32(r205[i6313], r210[i6313]);
            }
            /* gt [gt] -> r212 */
            for (long i6314 = 0; i6314 < 1024; ++i6314) {
                r212[i6314] = r211[i6314] > r195[0] ? 1 : 0;
            }
            /* select_n [select_n] -> r213 */
            for (long i6315 = 0; i6315 < 1024; ++i6315) {
                r213[i6315] = r212[i6315] == 0 ? r197[i6315] : (r201[i6315]);
            }
            /* select_n [select_n] -> r214 */
            for (long i6316 = 0; i6316 < 1024; ++i6316) {
                r214[i6316] = r212[i6316] == 0 ? r201[i6316] : (r198[i6316]);
            }
            memcpy(r196, r199, sizeof(int32_t) * 1);
            memcpy(r197, r213, sizeof(int32_t) * 1024);
            memcpy(r198, r214, sizeof(int32_t) * 1024);
        }
        memcpy(r215, r196, sizeof(int32_t) * 1);
        memcpy(r216, r197, sizeof(int32_t) * 1024);
        memcpy(r217, r198, sizeof(int32_t) * 1024);
        /* sub [sub] -> r218 */
        for (long i6317 = 0; i6317 < 1024; ++i6317) {
            r218[i6317] = sub32(r190[i6317], r217[i6317]);
        }
        memcpy(r219 + t3204 * 1024, r218, sizeof(int32_t) * 1024);
    }
    /* transpose [transpose] -> r220 */
    for (long i6318 = 0; i6318 < 16384; ++i6318) {
        long t6320 = i6318;
        long c63190 = t6320 / 16384; t6320 %= 16384;
        long c63191 = t6320 / 16384; t6320 %= 16384;
        long c63192 = t6320 / 1024; t6320 %= 1024;
        long c63193 = t6320;
        r220[i6318] = r219[c63190 * 1024 + c63191 * 1024 + c63192 * 1024 + c63193 * 1];
    }
    /* reshape [reshape] -> r221 */
    memcpy(r221, r220, sizeof(int32_t) * 16384);
    /* slice [slice] -> r222 */
    for (long i6321 = 0; i6321 < 16000; ++i6321) {
        long t6323 = i6321;
        long c63220 = t6323 / 16000; t6323 %= 16000;
        long c63221 = t6323 / 16000; t6323 %= 16000;
        long c63222 = t6323;
        r222[i6321] = r221[(0 + c63220 * 1) * 16384 + (0 + c63221 * 1) * 16384 + (0 + c63222 * 1) * 1];
    }
    /* transpose [transpose] -> r223 */
    for (long i6324 = 0; i6324 < 16000; ++i6324) {
        long t6326 = i6324;
        long c63250 = t6326 / 16000; t6326 %= 16000;
        long c63251 = t6326 / 16000; t6326 %= 16000;
        long c63252 = t6326;
        r223[i6324] = r222[c63250 * 16000 + c63251 * 16000 + c63252 * 1];
    }
    /* slice [slice] -> r224 */
    for (long i6327 = 0; i6327 < 16000; ++i6327) {
        long t6329 = i6327;
        long c63280 = t6329 / 16000; t6329 %= 16000;
        long c63281 = t6329 / 16000; t6329 %= 16000;
        long c63282 = t6329;
        r224[i6327] = r223[(0 + c63280 * 1) * 16000 + (0 + c63281 * 1) * 16000 + (0 + c63282 * 1) * 1];
    }
    /* reshape [squeeze] -> r225 */
    memcpy(r225, r224, sizeof(int32_t) * 16000);
    /* shra [shift_right_arithmetic] -> r226 */
    for (long i6330 = 0; i6330 < 16000; ++i6330) {
        r226[i6330] = asr32(r225[i6330], 1);
    }
    /* convert [convert_element_type] -> r229 */
    for (long i6331 = 0; i6331 < 1; ++i6331) {
        r229[i6331] = (int32_t)r227[0];
    }
    /* max [max] -> r230 */
    for (long i6332 = 0; i6332 < 16000; ++i6332) {
        r230[i6332] = max32(r229[0], r226[i6332]);
    }
    /* convert [convert_element_type] -> r231 */
    for (long i6333 = 0; i6333 < 1; ++i6333) {
        r231[i6333] = (int32_t)r228[0];
    }
    /* min [min] -> r232 */
    for (long i6334 = 0; i6334 < 16000; ++i6334) {
        r232[i6334] = min32(r231[0], r230[i6334]);
    }
    /* iota [iota] -> r233 */
    for (long i6335 = 0; i6335 < 8000; ++i6335) {
        long t6337 = i6335;
        long c63360 = t6337;
        r233[i6335] = (int32_t)c63360;
    }
    /* shl [mul] -> r234 */
    for (long i6338 = 0; i6338 < 8000; ++i6338) {
        r234[i6338] = shl32(r233[i6338], 1);
    }
    /* add [add] -> r235 */
    for (long i6339 = 0; i6339 < 8000; ++i6339) {
        r235[i6339] = add32(r14[0], r234[i6339]);
    }
    /* broadcast [broadcast_in_dim] -> r236 */
    for (long i6340 = 0; i6340 < 8000; ++i6340) {
        long t6342 = i6340;
        long c63410 = t6342 / 1; t6342 %= 1;
        long c63411 = t6342;
        r236[i6340] = r235[c63410 * 1];
    }
    /* gather [gather] -> r237 */
    for (long i6343 = 0; i6343 < 8000; ++i6343) {
        long t6345 = i6343;
        long c63440 = t6345 / 8000; t6345 %= 8000;
        long c63441 = t6345;
        long row6346 = c63441 * 1;
        long s6347 = clamp_start((long)r236[row6346 + 0], 16000, 1);
        r237[i6343] = r232[c63440 * 16000 + s6347 * 1];
    }
    /* shl [shift_left] -> r238 */
    for (long i6348 = 0; i6348 < 8000; ++i6348) {
        r238[i6348] = shl32(r237[i6348], 1);
    }
    /* mov [device_put] -> r239 */
    memcpy(r239, r1, sizeof(int32_t) * 80);
    /* rev [rev] -> r240 */
    for (long i6349 = 0; i6349 < 80; ++i6349) {
        long t6351 = i6349;
        long c63500 = t6351 / 16; t6351 %= 16;
        long c63501 = t6351;
        r240[i6349] = r239[c63500 * 16 + (16 - 1 - c63501) * 1];
    }
    /* reshape [reshape] -> r241 */
    memcpy(r241, r240, sizeof(int32_t) * 80);
    /* convert [convert_element_type] -> r242 */
    for (long i6352 = 0; i6352 < 1; ++i6352) {
        r242[i6352] = (int32_t)r14[0];
    }
    /* pad [pad] -> r243 */
    for (long i6353 = 0; i6353 < 8015; ++i6353) {
        r243[i6353] = r242[0];
    }
    for (long i6354 = 0; i6354 < 8000; ++i6354) {
        long t6356 = i6354;
        long c63550 = t6356 / 8000; t6356 %= 8000;
        long c63551 = t6356;
        long d6357 = 0 + c63550 * 1;
        long d6358 = 15 + c63551 * 1;
        if (d6357 >= 0 && d6357 < 1 && d6358 >= 0 && d6358 < 8015) r243[d6357 * 8015 + d6358 * 1] = r238[i6354];
    }
    /* convert [convert_element_type] -> r244 */
    for (long i6359 = 0; i6359 < 1; ++i6359) {
        r244[i6359] = (int32_t)r14[0];
    }
    /* pad [pad] -> r245 */
    for (long i6360 = 0; i6360 < 8207; ++i6360) {
        r245[i6360] = r244[0];
    }
    for (long i6361 = 0; i6361 < 8015; ++i6361) {
        long t6363 = i6361;
        long c63620 = t6363 / 8015; t6363 %= 8015;
        long c63621 = t6363;
        long d6364 = 0 + c63620 * 1;
        long d6365 = 0 + c63621 * 1;
        if (d6364 >= 0 && d6364 < 1 && d6365 >= 0 && d6365 < 8207) r245[d6364 * 8207 + d6365 * 1] = r243[i6361];
    }
    /* iota [iota] -> r246 */
    for (long i6366 = 0; i6366 < 1024; ++i6366) {
        long t6368 = i6366;
        long c63670 = t6368;
        r246[i6366] = (int32_t)c63670;
    }
    /* broadcast [broadcast_in_dim] -> r247 */
    for (long i6369 = 0; i6369 < 1024; ++i6369) {
        long t6371 = i6369;
        long c63700 = t6371 / 1; t6371 %= 1;
        long c63701 = t6371;
        r247[i6369] = r246[c63700 * 1];
    }
    /* iota [iota] -> r248 */
    for (long i6372 = 0; i6372 < 16; ++i6372) {
        long t6374 = i6372;
        long c63730 = t6374;
        r248[i6372] = (int32_t)c63730;
    }
    /* broadcast [broadcast_in_dim] -> r249 */
    for (long i6375 = 0; i6375 < 16; ++i6375) {
        long t6377 = i6375;
        long c63760 = t6377 / 16; t6377 %= 16;
        long c63761 = t6377;
        r249[i6375] = r248[c63761 * 1];
    }
    /* add [add] -> r250 */
    for (long i6378 = 0; i6378 < 16384; ++i6378) {
        long t6380 = i6378;
        long c63790 = t6380 / 16; t6380 %= 16;
        long c63791 = t6380;
        r250[i6378] = add32(r247[c63790 * 1], r249[c63791 * 1]);
    }
    /* iota [iota] -> r251 */
    for (long i6381 = 0; i6381 < 8; ++i6381) {
        long t6383 = i6381;
        long c63820 = t6383;
        r251[i6381] = (int32_t)c63820;
    }
    /* shl [mul] -> r252 */
    for (long i6384 = 0; i6384 < 8; ++i6384) {
        r252[i6384] = shl32(r251[i6384], 10);
    }
    /* loop [scan] -> r335 */
    memcpy(r253, r245, sizeof(int32_t) * 8207);
    memcpy(r254, r250, sizeof(int32_t) * 16384);
    memcpy(r255, r241, sizeof(int32_t) * 80);
    for (long t6385 = 0; t6385 < 8; ++t6385) {
        memcpy(r256, r252 + t6385 * 1, sizeof(int32_t) * 1);
        /* add [add] -> r257 */
        for (long i7386 = 0; i7386 < 1; ++i7386) {
            r257[i7386] = add32(r14[0], r9[0]);
        }
        /* select_n [select_n] -> r258 */
        for (long i7387 = 0; i7387 < 1; ++i7387) {
            r258[i7387] = r31[0] == 0 ? r14[0] : (r257[0]);
        }
        /* lt [lt] -> r259 */
        for (long i7388 = 0; i7388 < 1; ++i7388) {
            r259[i7388] = r256[0] < r14[0] ? 1 : 0;
        }
        /* add [add] -> r261 */
        for (long i7389 = 0; i7389 < 1; ++i7389) {
            r261[i7389] = add32(r256[0], r260[0]);
        }
        /* select_n [select_n] -> r262 */
        for (long i7390 = 0; i7390 < 1; ++i7390) {
            r262[i7390] = r259[0] == 0 ? r256[0] : (r261[0]);
        }
        /* dynamic_slice [dynamic_slice] -> r263 */
        long s7391 = clamp_start((long)r258[0], 1, 1);
        long s7392 = clamp_start((long)r262[0], 8207, 1039);
        {
        for (long i7393 = 0; i7393 < 1039; ++i7393) {
            long t7395 = i7393;
            long c73940 = t7395 / 1039; t7395 %= 1039;
            long c73941 = t7395;
            r263[i7393] = r253[(s7391 + c73940) * 8207 + (s7392 + c73941) * 1];
        }
        }
        /* lt [lt] -> r264 */
        for (long i7396 = 0; i7396 < 16384; ++i7396) {
            r264[i7396] = r254[i7396] < r14[0] ? 1 : 0;
        }
        /* add [add] -> r265 */
        for (long i7397 = 0; i7397 < 16384; ++i7397) {
            r265[i7397] = add32(r254[i7397], r39[0]);
        }
        /* select_n [select_n] -> r266 */
        for (long i7398 = 0; i7398 < 16384; ++i7398) {
            r266[i7398] = r264[i7398] == 0 ? r254[i7398] : (r265[i7398]);
        }
        /* broadcast [broadcast_in_dim] -> r267 */
        for (long i7399 = 0; i7399 < 16384; ++i7399) {
            long t7401 = i7399;
            long c74000 = t7401 / 16; t7401 %= 16;
            long c74001 = t7401 / 1; t7401 %= 1;
            long c74002 = t7401;
            r267[i7399] = r266[c74000 * 16 + c74001 * 1];
        }
        /* gather [gather] -> r268 */
        for (long i7402 = 0; i7402 < 16384; ++i7402) {
            long t7404 = i7402;
            long c74030 = t7404 / 16384; t7404 %= 16384;
            long c74031 = t7404 / 16; t7404 %= 16;
            long c74032 = t7404;
            long row7405 = c74031 * 16 + c74032 * 1;
            long s7406 = clamp_start((long)r267[row7405 + 0], 1039, 1);
            r268[i7402] = r263[c74030 * 1039 + s7406 * 1];
        }
        /* broadcast [broadcast_in_dim] -> r269 */
        for (long i7407 = 0; i7407 < 16384; ++i7407) {
            long t7409 = i7407;
            long c74080 = t7409 / 16384; t7409 %= 16384;
            long c74081 = t7409 / 16384; t7409 %= 16384;
            long c74082 = t7409 / 16; t7409 %= 16;
            long c74083 = t7409;
            r269[i7407] = r268[c74082 * 16 + c74083 * 1];
        }
        /* add [add] -> r270 */
        for (long i7410 = 0; i7410 < 81920; ++i7410) {
            long t7412 = i7410;
            long c74110 = t7412 / 16384; t7412 %= 16384;
            long c74111 = t7412 / 16384; t7412 %= 16384;
            long c74112 = t7412 / 16; t7412 %= 16;
            long c74113 = t7412;
            r270[i7410] = add32(r255[c74110 * 16 + c74113 * 1], r269[c74112 * 16 + c74113 * 1]);
        }
        /* convert [convert_element_type] -> r271 */
        for (long i7413 = 0; i7413 < 1; ++i7413) {
            r271[i7413] = (int32_t)r46[0];
        }
        /* max [max] -> r272 */
        for (long i7414 = 0; i7414 < 81920; ++i7414) {
            r272[i7414] = max32(r271[0], r270[i7414]);
        }
        /* convert [convert_element_type] -> r273 */
        for (long i7415 = 0; i7415 < 1; ++i7415) {
            r273[i7415] = (int32_t)r47[0];
        }
        /* min [min] -> r274 */
        for (long i7416 = 0; i7416 < 81920; ++i7416) {
            r274[i7416] = min32(r273[0], r272[i7416]);
        }
        /* sub [sub] -> r275 */
        for (long i7417 = 0; i7417 < 81920; ++i7417) {
            long t7419 = i7417;
            long c74180 = t7419 / 16384; t7419 %= 16384;
            long c74181 = t7419 / 16384; t7419 %= 16384;
            long c74182 = t7419 / 16; t7419 %= 16;
            long c74183 = t7419;
            r275[i7417] = sub32(r255[c74180 * 16 + c74183 * 1], r269[c74182 * 16 + c74183 * 1]);
        }
        /* convert [convert_element_type] -> r276 */
        for (long i7420 = 0; i7420 < 1; ++i7420) {
            r276[i7420] = (int32_t)r46[0];
        }
        /* max [max] -> r277 */
        for (long i7421 = 0; i7421 < 81920; ++i7421) {
            r277[i7421] = max32(r276[0], r275[i7421]);
        }
        /* convert [convert_element_type] -> r278 */
        for (long i7422 = 0; i7422 < 1; ++i7422) {
            r278[i7422] = (int32_t)r47[0];
        }
        /* min [min] -> r279 */
        for (long i7423 = 0; i7423 < 81920; ++i7423) {
            r279[i7423] = min32(r278[0], r277[i7423]);
        }
        /* abs [abs] -> r280 */
        for (long i7424 = 0; i7424 < 81920; ++i7424) {
            r280[i7424] = abs32(r274[i7424]);
        }
        /* reduce_max [reduce_max] -> r281 */
        for (long i7425 = 0; i7425 < 5120; ++i7425) {
            r281[i7425] = (-2147483647 - 1);
        }
        for (long i7426 = 0; i7426 < 81920; ++i7426) {
            long t7428 = i7426;
            long c74270 = t7428 / 16384; t7428 %= 16384;
            long c74271 = t7428 / 16384; t7428 %= 16384;
            long c74272 = t7428 / 16; t7428 %= 16;
            long c74273 = t7428;
            r281[c74270 * 1024 + c74271 * 1024 + c74272 * 1] = max32(r281[c74270 * 1024 + c74271 * 1024 + c74272 * 1], r280[i7426]);
        }
        /* sub [sub] -> r282 */
        for (long i7429 = 0; i7429 < 5120; ++i7429) {
            r282[i7429] = sub32(r281[i7429], r59[0]);
        }
        /* loop [scan] -> r304 */
        memcpy(r283, r274, sizeof(int32_t) * 81920);
        memcpy(r284, r59, sizeof(int32_t) * 1);
        memcpy(r285, r14, sizeof(int32_t) * 1);
        memcpy(r286, r282, sizeof(int32_t) * 5120);
        memcpy(r287, r281, sizeof(int32_t) * 5120);
        for (long t7430 = 0; t7430 < 12; ++t7430) {
            /* add [add] -> r288 */
            for (long i8431 = 0; i8431 < 1; ++i8431) {
                r288[i8431] = add32(r285[0], r9[0]);
            }
            /* add [add] -> r289 */
            for (long i8432 = 0; i8432 < 5120; ++i8432) {
                r289[i8432] = add32(r286[i8432], r287[i8432]);
            }
            /* shra [shift_right_arithmetic] -> r290 */
            for (long i8433 = 0; i8433 < 5120; ++i8433) {
                r290[i8433] = asr32(r289[i8433], 1);
            }
            /* broadcast [broadcast_in_dim] -> r291 */
            for (long i8434 = 0; i8434 < 5120; ++i8434) {
                long t8436 = i8434;
                long c84350 = t8436 / 1024; t8436 %= 1024;
                long c84351 = t8436 / 1024; t8436 %= 1024;
                long c84352 = t8436 / 1; t8436 %= 1;
                long c84353 = t8436;
                r291[i8434] = r290[c84350 * 1024 + c84352 * 1];
            }
            /* sub [sub] -> r292 */
            for (long i8437 = 0; i8437 < 81920; ++i8437) {
                long t8439 = i8437;
                long c84380 = t8439 / 16384; t8439 %= 16384;
                long c84381 = t8439 / 16384; t8439 %= 16384;
                long c84382 = t8439 / 16; t8439 %= 16;
                long c84383 = t8439;
                r292[i8437] = sub32(r283[c84380 * 16384 + c84382 * 16 + c84383 * 1], r291[c84380 * 1024 + c84382 * 1]);
            }
            /* max [max] -> r293 */
            for (long i8440 = 0; i8440 < 81920; ++i8440) {
                r293[i8440] = max32(r292[i8440], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r294 */
            for (long i8441 = 0; i8441 < 5120; ++i8441) {
                r294[i8441] = 0;
            }
            for (long i8442 = 0; i8442 < 81920; ++i8442) {
                long t8444 = i8442;
                long c84430 = t8444 / 16384; t8444 %= 16384;
                long c84431 = t8444 / 16384; t8444 %= 16384;
                long c84432 = t8444 / 16; t8444 %= 16;
                long c84433 = t8444;
                r294[c84430 * 1024 + c84431 * 1024 + c84432 * 1] = add32(r294[c84430 * 1024 + c84431 * 1024 + c84432 * 1], r293[i8442]);
            }
            /* neg [neg] -> r295 */
            for (long i8445 = 0; i8445 < 81920; ++i8445) {
                r295[i8445] = neg32(r283[i8445]);
            }
            /* broadcast [broadcast_in_dim] -> r296 */
            for (long i8446 = 0; i8446 < 5120; ++i8446) {
                long t8448 = i8446;
                long c84470 = t8448 / 1024; t8448 %= 1024;
                long c84471 = t8448 / 1024; t8448 %= 1024;
                long c84472 = t8448 / 1; t8448 %= 1;
                long c84473 = t8448;
                r296[i8446] = r290[c84470 * 1024 + c84472 * 1];
            }
            /* sub [sub] -> r297 */
            for (long i8449 = 0; i8449 < 81920; ++i8449) {
                long t8451 = i8449;
                long c84500 = t8451 / 16384; t8451 %= 16384;
                long c84501 = t8451 / 16384; t8451 %= 16384;
                long c84502 = t8451 / 16; t8451 %= 16;
                long c84503 = t8451;
                r297[i8449] = sub32(r295[c84500 * 16384 + c84502 * 16 + c84503 * 1], r296[c84500 * 1024 + c84502 * 1]);
            }
            /* max [max] -> r298 */
            for (long i8452 = 0; i8452 < 81920; ++i8452) {
                r298[i8452] = max32(r297[i8452], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r299 */
            for (long i8453 = 0; i8453 < 5120; ++i8453) {
                r299[i8453] = 0;
            }
            for (long i8454 = 0; i8454 < 81920; ++i8454) {
                long t8456 = i8454;
                long c84550 = t8456 / 16384; t8456 %= 16384;
                long c84551 = t8456 / 16384; t8456 %= 16384;
                long c84552 = t8456 / 16; t8456 %= 16;
                long c84553 = t8456;
                r299[c84550 * 1024 + c84551 * 1024 + c84552 * 1] = add32(r299[c84550 * 1024 + c84551 * 1024 + c84552 * 1], r298[i8454]);
            }
            /* add [add] -> r300 */
            for (long i8457 = 0; i8457 < 5120; ++i8457) {
                r300[i8457] = add32(r294[i8457], r299[i8457]);
            }
            /* gt [gt] -> r301 */
            for (long i8458 = 0; i8458 < 5120; ++i8458) {
                r301[i8458] = r300[i8458] > r284[0] ? 1 : 0;
            }
            /* select_n [select_n] -> r302 */
            for (long i8459 = 0; i8459 < 5120; ++i8459) {
                r302[i8459] = r301[i8459] == 0 ? r286[i8459] : (r290[i8459]);
            }
            /* select_n [select_n] -> r303 */
            for (long i8460 = 0; i8460 < 5120; ++i8460) {
                r303[i8460] = r301[i8460] == 0 ? r290[i8460] : (r287[i8460]);
            }
            memcpy(r285, r288, sizeof(int32_t) * 1);
            memcpy(r286, r302, sizeof(int32_t) * 5120);
            memcpy(r287, r303, sizeof(int32_t) * 5120);
        }
        memcpy(r304, r285, sizeof(int32_t) * 1);
        memcpy(r305, r286, sizeof(int32_t) * 5120);
        memcpy(r306, r287, sizeof(int32_t) * 5120);
        /* abs [abs] -> r307 */
        for (long i8461 = 0; i8461 < 81920; ++i8461) {
            r307[i8461] = abs32(r279[i8461]);
        }
        /* reduce_max [reduce_max] -> r308 */
        for (long i8462 = 0; i8462 < 5120; ++i8462) {
            r308[i8462] = (-2147483647 - 1);
        }
        for (long i8463 = 0; i8463 < 81920; ++i8463) {
            long t8465 = i8463;
            long c84640 = t8465 / 16384; t8465 %= 16384;
            long c84641 = t8465 / 16384; t8465 %= 16384;
            long c84642 = t8465 / 16; t8465 %= 16;
            long c84643 = t8465;
            r308[c84640 * 1024 + c84641 * 1024 + c84642 * 1] = max32(r308[c84640 * 1024 + c84641 * 1024 + c84642 * 1], r307[i8463]);
        }
        /* sub [sub] -> r309 */
        for (long i8466 = 0; i8466 < 5120; ++i8466) {
            r309[i8466] = sub32(r308[i8466], r59[0]);
        }
        /* loop [scan] -> r331 */
        memcpy(r310, r279, sizeof(int32_t) * 81920);
        memcpy(r311, r59, sizeof(int32_t) * 1);
        memcpy(r312, r14, sizeof(int32_t) * 1);
        memcpy(r313, r309, sizeof(int32_t) * 5120);
        memcpy(r314, r308, sizeof(int32_t) * 5120);
        for (long t8467 = 0; t8467 < 12; ++t8467) {
            /* add [add] -> r315 */
            for (long i9468 = 0; i9468 < 1; ++i9468) {
                r315[i9468] = add32(r312[0], r9[0]);
            }
            /* add [add] -> r316 */
            for (long i9469 = 0; i9469 < 5120; ++i9469) {
                r316[i9469] = add32(r313[i9469], r314[i9469]);
            }
            /* shra [shift_right_arithmetic] -> r317 */
            for (long i9470 = 0; i9470 < 5120; ++i9470) {
                r317[i9470] = asr32(r316[i9470], 1);
            }
            /* broadcast [broadcast_in_dim] -> r318 */
            for (long i9471 = 0; i9471 < 5120; ++i9471) {
                long t9473 = i9471;
                long c94720 = t9473 / 1024; t9473 %= 1024;
                long c94721 = t9473 / 1024; t9473 %= 1024;
                long c94722 = t9473 / 1; t9473 %= 1;
                long c94723 = t9473;
                r318[i9471] = r317[c94720 * 1024 + c94722 * 1];
            }
            /* sub [sub] -> r319 */
            for (long i9474 = 0; i9474 < 81920; ++i9474) {
                long t9476 = i9474;
                long c94750 = t9476 / 16384; t9476 %= 16384;
                long c94751 = t9476 / 16384; t9476 %= 16384;
                long c94752 = t9476 / 16; t9476 %= 16;
                long c94753 = t9476;
                r319[i9474] = sub32(r310[c94750 * 16384 + c94752 * 16 + c94753 * 1], r318[c94750 * 1024 + c94752 * 1]);
            }
            /* max [max] -> r320 */
            for (long i9477 = 0; i9477 < 81920; ++i9477) {
                r320[i9477] = max32(r319[i9477], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r321 */
            for (long i9478 = 0; i9478 < 5120; ++i9478) {
                r321[i9478] = 0;
            }
            for (long i9479 = 0; i9479 < 81920; ++i9479) {
                long t9481 = i9479;
                long c94800 = t9481 / 16384; t9481 %= 16384;
                long c94801 = t9481 / 16384; t9481 %= 16384;
                long c94802 = t9481 / 16; t9481 %= 16;
                long c94803 = t9481;
                r321[c94800 * 1024 + c94801 * 1024 + c94802 * 1] = add32(r321[c94800 * 1024 + c94801 * 1024 + c94802 * 1], r320[i9479]);
            }
            /* neg [neg] -> r322 */
            for (long i9482 = 0; i9482 < 81920; ++i9482) {
                r322[i9482] = neg32(r310[i9482]);
            }
            /* broadcast [broadcast_in_dim] -> r323 */
            for (long i9483 = 0; i9483 < 5120; ++i9483) {
                long t9485 = i9483;
                long c94840 = t9485 / 1024; t9485 %= 1024;
                long c94841 = t9485 / 1024; t9485 %= 1024;
                long c94842 = t9485 / 1; t9485 %= 1;
                long c94843 = t9485;
                r323[i9483] = r317[c94840 * 1024 + c94842 * 1];
            }
            /* sub [sub] -> r324 */
            for (long i9486 = 0; i9486 < 81920; ++i9486) {
                long t9488 = i9486;
                long c94870 = t9488 / 16384; t9488 %= 16384;
                long c94871 = t9488 / 16384; t9488 %= 16384;
                long c94872 = t9488 / 16; t9488 %= 16;
                long c94873 = t9488;
                r324[i9486] = sub32(r322[c94870 * 16384 + c94872 * 16 + c94873 * 1], r323[c94870 * 1024 + c94872 * 1]);
            }
            /* max [max] -> r325 */
            for (long i9489 = 0; i9489 < 81920; ++i9489) {
                r325[i9489] = max32(r324[i9489], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r326 */
            for (long i9490 = 0; i9490 < 5120; ++i9490) {
                r326[i9490] = 0;
            }
            for (long i9491 = 0; i9491 < 81920; ++i9491) {
                long t9493 = i9491;
                long c94920 = t9493 / 16384; t9493 %= 16384;
                long c94921 = t9493 / 16384; t9493 %= 16384;
                long c94922 = t9493 / 16; t9493 %= 16;
                long c94923 = t9493;
                r326[c94920 * 1024 + c94921 * 1024 + c94922 * 1] = add32(r326[c94920 * 1024 + c94921 * 1024 + c94922 * 1], r325[i9491]);
            }
            /* add [add] -> r327 */
            for (long i9494 = 0; i9494 < 5120; ++i9494) {
                r327[i9494] = add32(r321[i9494], r326[i9494]);
            }
            /* gt [gt] -> r328 */
            for (long i9495 = 0; i9495 < 5120; ++i9495) {
                r328[i9495] = r327[i9495] > r311[0] ? 1 : 0;
            }
            /* select_n [select_n] -> r329 */
            for (long i9496 = 0; i9496 < 5120; ++i9496) {
                r329[i9496] = r328[i9496] == 0 ? r313[i9496] : (r317[i9496]);
            }
            /* select_n [select_n] -> r330 */
            for (long i9497 = 0; i9497 < 5120; ++i9497) {
                r330[i9497] = r328[i9497] == 0 ? r317[i9497] : (r314[i9497]);
            }
            memcpy(r312, r315, sizeof(int32_t) * 1);
            memcpy(r313, r329, sizeof(int32_t) * 5120);
            memcpy(r314, r330, sizeof(int32_t) * 5120);
        }
        memcpy(r331, r312, sizeof(int32_t) * 1);
        memcpy(r332, r313, sizeof(int32_t) * 5120);
        memcpy(r333, r314, sizeof(int32_t) * 5120);
        /* sub [sub] -> r334 */
        for (long i9498 = 0; i9498 < 5120; ++i9498) {
            r334[i9498] = sub32(r306[i9498], r333[i9498]);
        }
        memcpy(r335 + t6385 * 5120, r334, sizeof(int32_t) * 5120);
    }
    /* transpose [transpose] -> r336 */
    for (long i9499 = 0; i9499 < 40960; ++i9499) {
        long t9501 = i9499;
        long c95000 = t9501 / 8192; t9501 %= 8192;
        long c95001 = t9501 / 8192; t9501 %= 8192;
        long c95002 = t9501 / 1024; t9501 %= 1024;
        long c95003 = t9501;
        r336[i9499] = r335[c95000 * 1024 + c95001 * 1024 + c95002 * 5120 + c95003 * 1];
    }
    /* reshape [reshape] -> r337 */
    memcpy(r337, r336, sizeof(int32_t) * 40960);
    /* slice [slice] -> r338 */
    for (long i9502 = 0; i9502 < 40000; ++i9502) {
        long t9504 = i9502;
        long c95030 = t9504 / 8000; t9504 %= 8000;
        long c95031 = t9504 / 8000; t9504 %= 8000;
        long c95032 = t9504;
        r338[i9502] = r337[(0 + c95030 * 1) * 8192 + (0 + c95031 * 1) * 8192 + (0 + c95032 * 1) * 1];
    }
    /* transpose [transpose] -> r339 */
    for (long i9505 = 0; i9505 < 40000; ++i9505) {
        long t9507 = i9505;
        long c95060 = t9507 / 40000; t9507 %= 40000;
        long c95061 = t9507 / 8000; t9507 %= 8000;
        long c95062 = t9507;
        r339[i9505] = r338[c95060 * 8000 + c95061 * 8000 + c95062 * 1];
    }
    /* max [max] -> r340 */
    for (long i9508 = 0; i9508 < 40000; ++i9508) {
        r340[i9508] = max32(r339[i9508], r14[0]);
    }
    /* reduce_sum [reduce_sum] -> r341 */
    for (long i9509 = 0; i9509 < 5; ++i9509) {
        r341[i9509] = 0;
    }
    for (long i9510 = 0; i9510 < 40000; ++i9510) {
        long t9512 = i9510;
        long c95110 = t9512 / 40000; t9512 %= 40000;
        long c95111 = t9512 / 8000; t9512 %= 8000;
        long c95112 = t9512;
        r341[c95110 * 5 + c95111 * 1] = add32(r341[c95110 * 5 + c95111 * 1], r340[i9510]);
    }
    /* shl [shift_left] -> r342 */
    for (long i9513 = 0; i9513 < 5; ++i9513) {
        r342[i9513] = shl32(r341[i9513], 1);
    }
    /* shl [shift_left] -> r343 */
    for (long i9514 = 0; i9514 < 8000; ++i9514) {
        r343[i9514] = shl32(r237[i9514], 1);
    }
    /* mov [device_put] -> r344 */
    memcpy(r344, r2, sizeof(int32_t) * 6);
    /* rev [rev] -> r345 */
    for (long i9515 = 0; i9515 < 6; ++i9515) {
        long t9517 = i9515;
        long c95160 = t9517 / 6; t9517 %= 6;
        long c95161 = t9517;
        r345[i9515] = r344[c95160 * 6 + (6 - 1 - c95161) * 1];
    }
    /* reshape [reshape] -> r346 */
    memcpy(r346, r345, sizeof(int32_t) * 6);
    /* convert [convert_element_type] -> r347 */
    for (long i9518 = 0; i9518 < 1; ++i9518) {
        r347[i9518] = (int32_t)r14[0];
    }
    /* pad [pad] -> r348 */
    for (long i9519 = 0; i9519 < 8005; ++i9519) {
        r348[i9519] = r347[0];
    }
    for (long i9520 = 0; i9520 < 8000; ++i9520) {
        long t9522 = i9520;
        long c95210 = t9522 / 8000; t9522 %= 8000;
        long c95211 = t9522;
        long d9523 = 0 + c95210 * 1;
        long d9524 = 5 + c95211 * 1;
        if (d9523 >= 0 && d9523 < 1 && d9524 >= 0 && d9524 < 8005) r348[d9523 * 8005 + d9524 * 1] = r343[i9520];
    }
    /* convert [convert_element_type] -> r349 */
    for (long i9525 = 0; i9525 < 1; ++i9525) {
        r349[i9525] = (int32_t)r14[0];
    }
    /* pad [pad] -> r350 */
    for (long i9526 = 0; i9526 < 8197; ++i9526) {
        r350[i9526] = r349[0];
    }
    for (long i9527 = 0; i9527 < 8005; ++i9527) {
        long t9529 = i9527;
        long c95280 = t9529 / 8005; t9529 %= 8005;
        long c95281 = t9529;
        long d9530 = 0 + c95280 * 1;
        long d9531 = 0 + c95281 * 1;
        if (d9530 >= 0 && d9530 < 1 && d9531 >= 0 && d9531 < 8197) r350[d9530 * 8197 + d9531 * 1] = r348[i9527];
    }
    /* iota [iota] -> r351 */
    for (long i9532 = 0; i9532 < 1024; ++i9532) {
        long t9534 = i9532;
        long c95330 = t9534;
        r351[i9532] = (int32_t)c95330;
    }
    /* broadcast [broadcast_in_dim] -> r352 */
    for (long i9535 = 0; i9535 < 1024; ++i9535) {
        long t9537 = i9535;
        long c95360 = t9537 / 1; t9537 %= 1;
        long c95361 = t9537;
        r352[i9535] = r351[c95360 * 1];
    }
    /* iota [iota] -> r353 */
    for (long i9538 = 0; i9538 < 6; ++i9538) {
        long t9540 = i9538;
        long c95390 = t9540;
        r353[i9538] = (int32_t)c95390;
    }
    /* broadcast [broadcast_in_dim] -> r354 */
    for (long i9541 = 0; i9541 < 6; ++i9541) {
        long t9543 = i9541;
        long c95420 = t9543 / 6; t9543 %= 6;
        long c95421 = t9543;
        r354[i9541] = r353[c95421 * 1];
    }
    /* add [add] -> r355 */
    for (long i9544 = 0; i9544 < 6144; ++i9544) {
        long t9546 = i9544;
        long c95450 = t9546 / 6; t9546 %= 6;
        long c95451 = t9546;
        r355[i9544] = add32(r352[c95450 * 1], r354[c95451 * 1]);
    }
    /* iota [iota] -> r356 */
    for (long i9547 = 0; i9547 < 8; ++i9547) {
        long t9549 = i9547;
        long c95480 = t9549;
        r356[i9547] = (int32_t)c95480;
    }
    /* shl [mul] -> r357 */
    for (long i9550 = 0; i9550 < 8; ++i9550) {
        r357[i9550] = shl32(r356[i9550], 10);
    }
    /* loop [scan] -> r440 */
    memcpy(r358, r350, sizeof(int32_t) * 8197);
    memcpy(r359, r355, sizeof(int32_t) * 6144);
    memcpy(r360, r346, sizeof(int32_t) * 6);
    for (long t9551 = 0; t9551 < 8; ++t9551) {
        memcpy(r361, r357 + t9551 * 1, sizeof(int32_t) * 1);
        /* add [add] -> r362 */
        for (long i10552 = 0; i10552 < 1; ++i10552) {
            r362[i10552] = add32(r14[0], r9[0]);
        }
        /* select_n [select_n] -> r363 */
        for (long i10553 = 0; i10553 < 1; ++i10553) {
            r363[i10553] = r31[0] == 0 ? r14[0] : (r362[0]);
        }
        /* lt [lt] -> r364 */
        for (long i10554 = 0; i10554 < 1; ++i10554) {
            r364[i10554] = r361[0] < r14[0] ? 1 : 0;
        }
        /* add [add] -> r366 */
        for (long i10555 = 0; i10555 < 1; ++i10555) {
            r366[i10555] = add32(r361[0], r365[0]);
        }
        /* select_n [select_n] -> r367 */
        for (long i10556 = 0; i10556 < 1; ++i10556) {
            r367[i10556] = r364[0] == 0 ? r361[0] : (r366[0]);
        }
        /* dynamic_slice [dynamic_slice] -> r368 */
        long s10557 = clamp_start((long)r363[0], 1, 1);
        long s10558 = clamp_start((long)r367[0], 8197, 1029);
        {
        for (long i10559 = 0; i10559 < 1029; ++i10559) {
            long t10561 = i10559;
            long c105600 = t10561 / 1029; t10561 %= 1029;
            long c105601 = t10561;
            r368[i10559] = r358[(s10557 + c105600) * 8197 + (s10558 + c105601) * 1];
        }
        }
        /* lt [lt] -> r369 */
        for (long i10562 = 0; i10562 < 6144; ++i10562) {
            r369[i10562] = r359[i10562] < r14[0] ? 1 : 0;
        }
        /* add [add] -> r370 */
        for (long i10563 = 0; i10563 < 6144; ++i10563) {
            r370[i10563] = add32(r359[i10563], r148[0]);
        }
        /* select_n [select_n] -> r371 */
        for (long i10564 = 0; i10564 < 6144; ++i10564) {
            r371[i10564] = r369[i10564] == 0 ? r359[i10564] : (r370[i10564]);
        }
        /* broadcast [broadcast_in_dim] -> r372 */
        for (long i10565 = 0; i10565 < 6144; ++i10565) {
            long t10567 = i10565;
            long c105660 = t10567 / 6; t10567 %= 6;
            long c105661 = t10567 / 1; t10567 %= 1;
            long c105662 = t10567;
            r372[i10565] = r371[c105660 * 6 + c105661 * 1];
        }
        /* gather [gather] -> r373 */
        for (long i10568 = 0; i10568 < 6144; ++i10568) {
            long t10570 = i10568;
            long c105690 = t10570 / 6144; t10570 %= 6144;
            long c105691 = t10570 / 6; t10570 %= 6;
            long c105692 = t10570;
            long row10571 = c105691 * 6 + c105692 * 1;
            long s10572 = clamp_start((long)r372[row10571 + 0], 1029, 1);
            r373[i10568] = r368[c105690 * 1029 + s10572 * 1];
        }
        /* broadcast [broadcast_in_dim] -> r374 */
        for (long i10573 = 0; i10573 < 6144; ++i10573) {
            long t10575 = i10573;
            long c105740 = t10575 / 6144; t10575 %= 6144;
            long c105741 = t10575 / 6144; t10575 %= 6144;
            long c105742 = t10575 / 6; t10575 %= 6;
            long c105743 = t10575;
            r374[i10573] = r373[c105742 * 6 + c105743 * 1];
        }
        /* add [add] -> r375 */
        for (long i10576 = 0; i10576 < 6144; ++i10576) {
            long t10578 = i10576;
            long c105770 = t10578 / 6144; t10578 %= 6144;
            long c105771 = t10578 / 6144; t10578 %= 6144;
            long c105772 = t10578 / 6; t10578 %= 6;
            long c105773 = t10578;
            r375[i10576] = add32(r360[c105773 * 1], r374[c105772 * 6 + c105773 * 1]);
        }
        /* convert [convert_element_type] -> r376 */
        for (long i10579 = 0; i10579 < 1; ++i10579) {
            r376[i10579] = (int32_t)r46[0];
        }
        /* max [max] -> r377 */
        for (long i10580 = 0; i10580 < 6144; ++i10580) {
            r377[i10580] = max32(r376[0], r375[i10580]);
        }
        /* convert [convert_element_type] -> r378 */
        for (long i10581 = 0; i10581 < 1; ++i10581) {
            r378[i10581] = (int32_t)r47[0];
        }
        /* min [min] -> r379 */
        for (long i10582 = 0; i10582 < 6144; ++i10582) {
            r379[i10582] = min32(r378[0], r377[i10582]);
        }
        /* sub [sub] -> r380 */
        for (long i10583 = 0; i10583 < 6144; ++i10583) {
            long t10585 = i10583;
            long c105840 = t10585 / 6144; t10585 %= 6144;
            long c105841 = t10585 / 6144; t10585 %= 6144;
            long c105842 = t10585 / 6; t10585 %= 6;
            long c105843 = t10585;
            r380[i10583] = sub32(r360[c105843 * 1], r374[c105842 * 6 + c105843 * 1]);
        }
        /* convert [convert_element_type] -> r381 */
        for (long i10586 = 0; i10586 < 1; ++i10586) {
            r381[i10586] = (int32_t)r46[0];
        }
        /* max [max] -> r382 */
        for (long i10587 = 0; i10587 < 6144; ++i10587) {
            r382[i10587] = max32(r381[0], r380[i10587]);
        }
        /* convert [convert_element_type] -> r383 */
        for (long i10588 = 0; i10588 < 1; ++i10588) {
            r383[i10588] = (int32_t)r47[0];
        }
        /* min [min] -> r384 */
        for (long i10589 = 0; i10589 < 6144; ++i10589) {
            r384[i10589] = min32(r383[0], r382[i10589]);
        }
        /* abs [abs] -> r385 */
        for (long i10590 = 0; i10590 < 6144; ++i10590) {
            r385[i10590] = abs32(r379[i10590]);
        }
        /* reduce_max [reduce_max] -> r386 */
        for (long i10591 = 0; i10591 < 1024; ++i10591) {
            r386[i10591] = (-2147483647 - 1);
        }
        for (long i10592 = 0; i10592 < 6144; ++i10592) {
            long t10594 = i10592;
            long c105930 = t10594 / 6144; t10594 %= 6144;
            long c105931 = t10594 / 6144; t10594 %= 6144;
            long c105932 = t10594 / 6; t10594 %= 6;
            long c105933 = t10594;
            r386[c105930 * 1024 + c105931 * 1024 + c105932 * 1] = max32(r386[c105930 * 1024 + c105931 * 1024 + c105932 * 1], r385[i10592]);
        }
        /* sub [sub] -> r387 */
        for (long i10595 = 0; i10595 < 1024; ++i10595) {
            r387[i10595] = sub32(r386[i10595], r59[0]);
        }
        /* loop [scan] -> r409 */
        memcpy(r388, r379, sizeof(int32_t) * 6144);
        memcpy(r389, r59, sizeof(int32_t) * 1);
        memcpy(r390, r14, sizeof(int32_t) * 1);
        memcpy(r391, r387, sizeof(int32_t) * 1024);
        memcpy(r392, r386, sizeof(int32_t) * 1024);
        for (long t10596 = 0; t10596 < 12; ++t10596) {
            /* add [add] -> r393 */
            for (long i11597 = 0; i11597 < 1; ++i11597) {
                r393[i11597] = add32(r390[0], r9[0]);
            }
            /* add [add] -> r394 */
            for (long i11598 = 0; i11598 < 1024; ++i11598) {
                r394[i11598] = add32(r391[i11598], r392[i11598]);
            }
            /* shra [shift_right_arithmetic] -> r395 */
            for (long i11599 = 0; i11599 < 1024; ++i11599) {
                r395[i11599] = asr32(r394[i11599], 1);
            }
            /* broadcast [broadcast_in_dim] -> r396 */
            for (long i11600 = 0; i11600 < 1024; ++i11600) {
                long t11602 = i11600;
                long c116010 = t11602 / 1024; t11602 %= 1024;
                long c116011 = t11602 / 1024; t11602 %= 1024;
                long c116012 = t11602 / 1; t11602 %= 1;
                long c116013 = t11602;
                r396[i11600] = r395[c116012 * 1];
            }
            /* sub [sub] -> r397 */
            for (long i11603 = 0; i11603 < 6144; ++i11603) {
                long t11605 = i11603;
                long c116040 = t11605 / 6144; t11605 %= 6144;
                long c116041 = t11605 / 6144; t11605 %= 6144;
                long c116042 = t11605 / 6; t11605 %= 6;
                long c116043 = t11605;
                r397[i11603] = sub32(r388[c116042 * 6 + c116043 * 1], r396[c116042 * 1]);
            }
            /* max [max] -> r398 */
            for (long i11606 = 0; i11606 < 6144; ++i11606) {
                r398[i11606] = max32(r397[i11606], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r399 */
            for (long i11607 = 0; i11607 < 1024; ++i11607) {
                r399[i11607] = 0;
            }
            for (long i11608 = 0; i11608 < 6144; ++i11608) {
                long t11610 = i11608;
                long c116090 = t11610 / 6144; t11610 %= 6144;
                long c116091 = t11610 / 6144; t11610 %= 6144;
                long c116092 = t11610 / 6; t11610 %= 6;
                long c116093 = t11610;
                r399[c116090 * 1024 + c116091 * 1024 + c116092 * 1] = add32(r399[c116090 * 1024 + c116091 * 1024 + c116092 * 1], r398[i11608]);
            }
            /* neg [neg] -> r400 */
            for (long i11611 = 0; i11611 < 6144; ++i11611) {
                r400[i11611] = neg32(r388[i11611]);
            }
            /* broadcast [broadcast_in_dim] -> r401 */
            for (long i11612 = 0; i11612 < 1024; ++i11612) {
                long t11614 = i11612;
                long c116130 = t11614 / 1024; t11614 %= 1024;
                long c116131 = t11614 / 1024; t11614 %= 1024;
                long c116132 = t11614 / 1; t11614 %= 1;
                long c116133 = t11614;
                r401[i11612] = r395[c116132 * 1];
            }
            /* sub [sub] -> r402 */
            for (long i11615 = 0; i11615 < 6144; ++i11615) {
                long t11617 = i11615;
                long c116160 = t11617 / 6144; t11617 %= 6144;
                long c116161 = t11617 / 6144; t11617 %= 6144;
                long c116162 = t11617 / 6; t11617 %= 6;
                long c116163 = t11617;
                r402[i11615] = sub32(r400[c116162 * 6 + c116163 * 1], r401[c116162 * 1]);
            }
            /* max [max] -> r403 */
            for (long i11618 = 0; i11618 < 6144; ++i11618) {
                r403[i11618] = max32(r402[i11618], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r404 */
            for (long i11619 = 0; i11619 < 1024; ++i11619) {
                r404[i11619] = 0;
            }
            for (long i11620 = 0; i11620 < 6144; ++i11620) {
                long t11622 = i11620;
                long c116210 = t11622 / 6144; t11622 %= 6144;
                long c116211 = t11622 / 6144; t11622 %= 6144;
                long c116212 = t11622 / 6; t11622 %= 6;
                long c116213 = t11622;
                r404[c116210 * 1024 + c116211 * 1024 + c116212 * 1] = add32(r404[c116210 * 1024 + c116211 * 1024 + c116212 * 1], r403[i11620]);
            }
            /* add [add] -> r405 */
            for (long i11623 = 0; i11623 < 1024; ++i11623) {
                r405[i11623] = add32(r399[i11623], r404[i11623]);
            }
            /* gt [gt] -> r406 */
            for (long i11624 = 0; i11624 < 1024; ++i11624) {
                r406[i11624] = r405[i11624] > r389[0] ? 1 : 0;
            }
            /* select_n [select_n] -> r407 */
            for (long i11625 = 0; i11625 < 1024; ++i11625) {
                r407[i11625] = r406[i11625] == 0 ? r391[i11625] : (r395[i11625]);
            }
            /* select_n [select_n] -> r408 */
            for (long i11626 = 0; i11626 < 1024; ++i11626) {
                r408[i11626] = r406[i11626] == 0 ? r395[i11626] : (r392[i11626]);
            }
            memcpy(r390, r393, sizeof(int32_t) * 1);
            memcpy(r391, r407, sizeof(int32_t) * 1024);
            memcpy(r392, r408, sizeof(int32_t) * 1024);
        }
        memcpy(r409, r390, sizeof(int32_t) * 1);
        memcpy(r410, r391, sizeof(int32_t) * 1024);
        memcpy(r411, r392, sizeof(int32_t) * 1024);
        /* abs [abs] -> r412 */
        for (long i11627 = 0; i11627 < 6144; ++i11627) {
            r412[i11627] = abs32(r384[i11627]);
        }
        /* reduce_max [reduce_max] -> r413 */
        for (long i11628 = 0; i11628 < 1024; ++i11628) {
            r413[i11628] = (-2147483647 - 1);
        }
        for (long i11629 = 0; i11629 < 6144; ++i11629) {
            long t11631 = i11629;
            long c116300 = t11631 / 6144; t11631 %= 6144;
            long c116301 = t11631 / 6144; t11631 %= 6144;
            long c116302 = t11631 / 6; t11631 %= 6;
            long c116303 = t11631;
            r413[c116300 * 1024 + c116301 * 1024 + c116302 * 1] = max32(r413[c116300 * 1024 + c116301 * 1024 + c116302 * 1], r412[i11629]);
        }
        /* sub [sub] -> r414 */
        for (long i11632 = 0; i11632 < 1024; ++i11632) {
            r414[i11632] = sub32(r413[i11632], r59[0]);
        }
        /* loop [scan] -> r436 */
        memcpy(r415, r384, sizeof(int32_t) * 6144);
        memcpy(r416, r59, sizeof(int32_t) * 1);
        memcpy(r417, r14, sizeof(int32_t) * 1);
        memcpy(r418, r414, sizeof(int32_t) * 1024);
        memcpy(r419, r413, sizeof(int32_t) * 1024);
        for (long t11633 = 0; t11633 < 12; ++t11633) {
            /* add [add] -> r420 */
            for (long i12634 = 0; i12634 < 1; ++i12634) {
                r420[i12634] = add32(r417[0], r9[0]);
            }
            /* add [add] -> r421 */
            for (long i12635 = 0; i12635 < 1024; ++i12635) {
                r421[i12635] = add32(r418[i12635], r419[i12635]);
            }
            /* shra [shift_right_arithmetic] -> r422 */
            for (long i12636 = 0; i12636 < 1024; ++i12636) {
                r422[i12636] = asr32(r421[i12636], 1);
            }
            /* broadcast [broadcast_in_dim] -> r423 */
            for (long i12637 = 0; i12637 < 1024; ++i12637) {
                long t12639 = i12637;
                long c126380 = t12639 / 1024; t12639 %= 1024;
                long c126381 = t12639 / 1024; t12639 %= 1024;
                long c126382 = t12639 / 1; t12639 %= 1;
                long c126383 = t12639;
                r423[i12637] = r422[c126382 * 1];
            }
            /* sub [sub] -> r424 */
            for (long i12640 = 0; i12640 < 6144; ++i12640) {
                long t12642 = i12640;
                long c126410 = t12642 / 6144; t12642 %= 6144;
                long c126411 = t12642 / 6144; t12642 %= 6144;
                long c126412 = t12642 / 6; t12642 %= 6;
                long c126413 = t12642;
                r424[i12640] = sub32(r415[c126412 * 6 + c126413 * 1], r423[c126412 * 1]);
            }
            /* max [max] -> r425 */
            for (long i12643 = 0; i12643 < 6144; ++i12643) {
                r425[i12643] = max32(r424[i12643], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r426 */
            for (long i12644 = 0; i12644 < 1024; ++i12644) {
                r426[i12644] = 0;
            }
            for (long i12645 = 0; i12645 < 6144; ++i12645) {
                long t12647 = i12645;
                long c126460 = t12647 / 6144; t12647 %= 6144;
                long c126461 = t12647 / 6144; t12647 %= 6144;
                long c126462 = t12647 / 6; t12647 %= 6;
                long c126463 = t12647;
                r426[c126460 * 1024 + c126461 * 1024 + c126462 * 1] = add32(r426[c126460 * 1024 + c126461 * 1024 + c126462 * 1], r425[i12645]);
            }
            /* neg [neg] -> r427 */
            for (long i12648 = 0; i12648 < 6144; ++i12648) {
                r427[i12648] = neg32(r415[i12648]);
            }
            /* broadcast [broadcast_in_dim] -> r428 */
            for (long i12649 = 0; i12649 < 1024; ++i12649) {
                long t12651 = i12649;
                long c126500 = t12651 / 1024; t12651 %= 1024;
                long c126501 = t12651 / 1024; t12651 %= 1024;
                long c126502 = t12651 / 1; t12651 %= 1;
                long c126503 = t12651;
                r428[i12649] = r422[c126502 * 1];
            }
            /* sub [sub] -> r429 */
            for (long i12652 = 0; i12652 < 6144; ++i12652) {
                long t12654 = i12652;
                long c126530 = t12654 / 6144; t12654 %= 6144;
                long c126531 = t12654 / 6144; t12654 %= 6144;
                long c126532 = t12654 / 6; t12654 %= 6;
                long c126533 = t12654;
                r429[i12652] = sub32(r427[c126532 * 6 + c126533 * 1], r428[c126532 * 1]);
            }
            /* max [max] -> r430 */
            for (long i12655 = 0; i12655 < 6144; ++i12655) {
                r430[i12655] = max32(r429[i12655], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r431 */
            for (long i12656 = 0; i12656 < 1024; ++i12656) {
                r431[i12656] = 0;
            }
            for (long i12657 = 0; i12657 < 6144; ++i12657) {
                long t12659 = i12657;
                long c126580 = t12659 / 6144; t12659 %= 6144;
                long c126581 = t12659 / 6144; t12659 %= 6144;
                long c126582 = t12659 / 6; t12659 %= 6;
                long c126583 = t12659;
                r431[c126580 * 1024 + c126581 * 1024 + c126582 * 1] = add32(r431[c126580 * 1024 + c126581 * 1024 + c126582 * 1], r430[i12657]);
            }
            /* add [add] -> r432 */
            for (long i12660 = 0; i12660 < 1024; ++i12660) {
                r432[i12660] = add32(r426[i12660], r431[i12660]);
            }
            /* gt [gt] -> r433 */
            for (long i12661 = 0; i12661 < 1024; ++i12661) {
                r433[i12661] = r432[i12661] > r416[0] ? 1 : 0;
            }
            /* select_n [select_n] -> r434 */
            for (long i12662 = 0; i12662 < 1024; ++i12662) {
                r434[i12662] = r433[i12662] == 0 ? r418[i12662] : (r422[i12662]);
            }
            /* select_n [select_n] -> r435 */
            for (long i12663 = 0; i12663 < 1024; ++i12663) {
                r435[i12663] = r433[i12663] == 0 ? r422[i12663] : (r419[i12663]);
            }
            memcpy(r417, r420, sizeof(int32_t) * 1);
            memcpy(r418, r434, sizeof(int32_t) * 1024);
            memcpy(r419, r435, sizeof(int32_t) * 1024);
        }
        memcpy(r436, r417, sizeof(int32_t) * 1);
        memcpy(r437, r418, sizeof(int32_t) * 1024);
        memcpy(r438, r419, sizeof(int32_t) * 1024);
        /* sub [sub] -> r439 */
        for (long i12664 = 0; i12664 < 1024; ++i12664) {
            r439[i12664] = sub32(r411[i12664], r438[i12664]);
        }
        memcpy(r440 + t9551 * 1024, r439, sizeof(int32_t) * 1024);
    }
    /* transpose [transpose] -> r441 */
    for (long i12665 = 0; i12665 < 8192; ++i12665) {
        long t12667 = i12665;
        long c126660 = t12667 / 8192; t12667 %= 8192;
        long c126661 = t12667 / 8192; t12667 %= 8192;
        long c126662 = t12667 / 1024; t12667 %= 1024;
        long c126663 = t12667;
        r441[i12665] = r440[c126660 * 1024 + c126661 * 1024 + c126662 * 1024 + c126663 * 1];
    }
    /* reshape [reshape] -> r442 */
    memcpy(r442, r441, sizeof(int32_t) * 8192);
    /* slice [slice] -> r443 */
    for (long i12668 = 0; i12668 < 8000; ++i12668) {
        long t12670 = i12668;
        long c126690 = t12670 / 8000; t12670 %= 8000;
        long c126691 = t12670 / 8000; t12670 %= 8000;
        long c126692 = t12670;
        r443[i12668] = r442[(0 + c126690 * 1) * 8192 + (0 + c126691 * 1) * 8192 + (0 + c126692 * 1) * 1];
    }
    /* transpose [transpose] -> r444 */
    for (long i12671 = 0; i12671 < 8000; ++i12671) {
        long t12673 = i12671;
        long c126720 = t12673 / 8000; t12673 %= 8000;
        long c126721 = t12673 / 8000; t12673 %= 8000;
        long c126722 = t12673;
        r444[i12671] = r443[c126720 * 8000 + c126721 * 8000 + c126722 * 1];
    }
    /* slice [slice] -> r445 */
    for (long i12674 = 0; i12674 < 8000; ++i12674) {
        long t12676 = i12674;
        long c126750 = t12676 / 8000; t12676 %= 8000;
        long c126751 = t12676 / 8000; t12676 %= 8000;
        long c126752 = t12676;
        r445[i12674] = r444[(0 + c126750 * 1) * 8000 + (0 + c126751 * 1) * 8000 + (0 + c126752 * 1) * 1];
    }
    /* reshape [squeeze] -> r446 */
    memcpy(r446, r445, sizeof(int32_t) * 8000);
    /* shra [shift_right_arithmetic] -> r447 */
    for (long i12677 = 0; i12677 < 8000; ++i12677) {
        r447[i12677] = asr32(r446[i12677], 1);
    }
    /* convert [convert_element_type] -> r448 */
    for (long i12678 = 0; i12678 < 1; ++i12678) {
        r448[i12678] = (int32_t)r227[0];
    }
    /* max [max] -> r449 */
    for (long i12679 = 0; i12679 < 8000; ++i12679) {
        r449[i12679] = max32(r448[0], r447[i12679]);
    }
    /* convert [convert_element_type] -> r450 */
    for (long i12680 = 0; i12680 < 1; ++i12680) {
        r450[i12680] = (int32_t)r228[0];
    }
    /* min [min] -> r451 */
    for (long i12681 = 0; i12681 < 8000; ++i12681) {
        r451[i12681] = min32(r450[0], r449[i12681]);
    }
    /* iota [iota] -> r452 */
    for (long i12682 = 0; i12682 < 4000; ++i12682) {
        long t12684 = i12682;
        long c126830 = t12684;
        r452[i12682] = (int32_t)c126830;
    }
    /* shl [mul] -> r453 */
    for (long i12685 = 0; i12685 < 4000; ++i12685) {
        r453[i12685] = shl32(r452[i12685], 1);
    }
    /* add [add] -> r454 */
    for (long i12686 = 0; i12686 < 4000; ++i12686) {
        r454[i12686] = add32(r14[0], r453[i12686]);
    }
    /* broadcast [broadcast_in_dim] -> r455 */
    for (long i12687 = 0; i12687 < 4000; ++i12687) {
        long t12689 = i12687;
        long c126880 = t12689 / 1; t12689 %= 1;
        long c126881 = t12689;
        r455[i12687] = r454[c126880 * 1];
    }
    /* gather [gather] -> r456 */
    for (long i12690 = 0; i12690 < 4000; ++i12690) {
        long t12692 = i12690;
        long c126910 = t12692 / 4000; t12692 %= 4000;
        long c126911 = t12692;
        long row12693 = c126911 * 1;
        long s12694 = clamp_start((long)r455[row12693 + 0], 8000, 1);
        r456[i12690] = r451[c126910 * 8000 + s12694 * 1];
    }
    /* shl [shift_left] -> r457 */
    for (long i12695 = 0; i12695 < 4000; ++i12695) {
        r457[i12695] = shl32(r456[i12695], 1);
    }
    /* mov [device_put] -> r458 */
    memcpy(r458, r1, sizeof(int32_t) * 80);
    /* rev [rev] -> r459 */
    for (long i12696 = 0; i12696 < 80; ++i12696) {
        long t12698 = i12696;
        long c126970 = t12698 / 16; t12698 %= 16;
        long c126971 = t12698;
        r459[i12696] = r458[c126970 * 16 + (16 - 1 - c126971) * 1];
    }
    /* reshape [reshape] -> r460 */
    memcpy(r460, r459, sizeof(int32_t) * 80);
    /* convert [convert_element_type] -> r461 */
    for (long i12699 = 0; i12699 < 1; ++i12699) {
        r461[i12699] = (int32_t)r14[0];
    }
    /* pad [pad] -> r462 */
    for (long i12700 = 0; i12700 < 4015; ++i12700) {
        r462[i12700] = r461[0];
    }
    for (long i12701 = 0; i12701 < 4000; ++i12701) {
        long t12703 = i12701;
        long c127020 = t12703 / 4000; t12703 %= 4000;
        long c127021 = t12703;
        long d12704 = 0 + c127020 * 1;
        long d12705 = 15 + c127021 * 1;
        if (d12704 >= 0 && d12704 < 1 && d12705 >= 0 && d12705 < 4015) r462[d12704 * 4015 + d12705 * 1] = r457[i12701];
    }
    /* convert [convert_element_type] -> r463 */
    for (long i12706 = 0; i12706 < 1; ++i12706) {
        r463[i12706] = (int32_t)r14[0];
    }
    /* pad [pad] -> r464 */
    for (long i12707 = 0; i12707 < 4111; ++i12707) {
        r464[i12707] = r463[0];
    }
    for (long i12708 = 0; i12708 < 4015; ++i12708) {
        long t12710 = i12708;
        long c127090 = t12710 / 4015; t12710 %= 4015;
        long c127091 = t12710;
        long d12711 = 0 + c127090 * 1;
        long d12712 = 0 + c127091 * 1;
        if (d12711 >= 0 && d12711 < 1 && d12712 >= 0 && d12712 < 4111) r464[d12711 * 4111 + d12712 * 1] = r462[i12708];
    }
    /* iota [iota] -> r465 */
    for (long i12713 = 0; i12713 < 1024; ++i12713) {
        long t12715 = i12713;
        long c127140 = t12715;
        r465[i12713] = (int32_t)c127140;
    }
    /* broadcast [broadcast_in_dim] -> r466 */
    for (long i12716 = 0; i12716 < 1024; ++i12716) {
        long t12718 = i12716;
        long c127170 = t12718 / 1; t12718 %= 1;
        long c127171 = t12718;
        r466[i12716] = r465[c127170 * 1];
    }
    /* iota [iota] -> r467 */
    for (long i12719 = 0; i12719 < 16; ++i12719) {
        long t12721 = i12719;
        long c127200 = t12721;
        r467[i12719] = (int32_t)c127200;
    }
    /* broadcast [broadcast_in_dim] -> r468 */
    for (long i12722 = 0; i12722 < 16; ++i12722) {
        long t12724 = i12722;
        long c127230 = t12724 / 16; t12724 %= 16;
        long c127231 = t12724;
        r468[i12722] = r467[c127231 * 1];
    }
    /* add [add] -> r469 */
    for (long i12725 = 0; i12725 < 16384; ++i12725) {
        long t12727 = i12725;
        long c127260 = t12727 / 16; t12727 %= 16;
        long c127261 = t12727;
        r469[i12725] = add32(r466[c127260 * 1], r468[c127261 * 1]);
    }
    /* iota [iota] -> r470 */
    for (long i12728 = 0; i12728 < 4; ++i12728) {
        long t12730 = i12728;
        long c127290 = t12730;
        r470[i12728] = (int32_t)c127290;
    }
    /* shl [mul] -> r471 */
    for (long i12731 = 0; i12731 < 4; ++i12731) {
        r471[i12731] = shl32(r470[i12731], 10);
    }
    /* loop [scan] -> r554 */
    memcpy(r472, r464, sizeof(int32_t) * 4111);
    memcpy(r473, r469, sizeof(int32_t) * 16384);
    memcpy(r474, r460, sizeof(int32_t) * 80);
    for (long t12732 = 0; t12732 < 4; ++t12732) {
        memcpy(r475, r471 + t12732 * 1, sizeof(int32_t) * 1);
        /* add [add] -> r476 */
        for (long i13733 = 0; i13733 < 1; ++i13733) {
            r476[i13733] = add32(r14[0], r9[0]);
        }
        /* select_n [select_n] -> r477 */
        for (long i13734 = 0; i13734 < 1; ++i13734) {
            r477[i13734] = r31[0] == 0 ? r14[0] : (r476[0]);
        }
        /* lt [lt] -> r478 */
        for (long i13735 = 0; i13735 < 1; ++i13735) {
            r478[i13735] = r475[0] < r14[0] ? 1 : 0;
        }
        /* add [add] -> r480 */
        for (long i13736 = 0; i13736 < 1; ++i13736) {
            r480[i13736] = add32(r475[0], r479[0]);
        }
        /* select_n [select_n] -> r481 */
        for (long i13737 = 0; i13737 < 1; ++i13737) {
            r481[i13737] = r478[0] == 0 ? r475[0] : (r480[0]);
        }
        /* dynamic_slice [dynamic_slice] -> r482 */
        long s13738 = clamp_start((long)r477[0], 1, 1);
        long s13739 = clamp_start((long)r481[0], 4111, 1039);
        {
        for (long i13740 = 0; i13740 < 1039; ++i13740) {
            long t13742 = i13740;
            long c137410 = t13742 / 1039; t13742 %= 1039;
            long c137411 = t13742;
            r482[i13740] = r472[(s13738 + c137410) * 4111 + (s13739 + c137411) * 1];
        }
        }
        /* lt [lt] -> r483 */
        for (long i13743 = 0; i13743 < 16384; ++i13743) {
            r483[i13743] = r473[i13743] < r14[0] ? 1 : 0;
        }
        /* add [add] -> r484 */
        for (long i13744 = 0; i13744 < 16384; ++i13744) {
            r484[i13744] = add32(r473[i13744], r39[0]);
        }
        /* select_n [select_n] -> r485 */
        for (long i13745 = 0; i13745 < 16384; ++i13745) {
            r485[i13745] = r483[i13745] == 0 ? r473[i13745] : (r484[i13745]);
        }
        /* broadcast [broadcast_in_dim] -> r486 */
        for (long i13746 = 0; i13746 < 16384; ++i13746) {
            long t13748 = i13746;
            long c137470 = t13748 / 16; t13748 %= 16;
            long c137471 = t13748 / 1; t13748 %= 1;
            long c137472 = t13748;
            r486[i13746] = r485[c137470 * 16 + c137471 * 1];
        }
        /* gather [gather] -> r487 */
        for (long i13749 = 0; i13749 < 16384; ++i13749) {
            long t13751 = i13749;
            long c137500 = t13751 / 16384; t13751 %= 16384;
            long c137501 = t13751 / 16; t13751 %= 16;
            long c137502 = t13751;
            long row13752 = c137501 * 16 + c137502 * 1;
            long s13753 = clamp_start((long)r486[row13752 + 0], 1039, 1);
            r487[i13749] = r482[c137500 * 1039 + s13753 * 1];
        }
        /* broadcast [broadcast_in_dim] -> r488 */
        for (long i13754 = 0; i13754 < 16384; ++i13754) {
            long t13756 = i13754;
            long c137550 = t13756 / 16384; t13756 %= 16384;
            long c137551 = t13756 / 16384; t13756 %= 16384;
            long c137552 = t13756 / 16; t13756 %= 16;
            long c137553 = t13756;
            r488[i13754] = r487[c137552 * 16 + c137553 * 1];
        }
        /* add [add] -> r489 */
        for (long i13757 = 0; i13757 < 81920; ++i13757) {
            long t13759 = i13757;
            long c137580 = t13759 / 16384; t13759 %= 16384;
            long c137581 = t13759 / 16384; t13759 %= 16384;
            long c137582 = t13759 / 16; t13759 %= 16;
            long c137583 = t13759;
            r489[i13757] = add32(r474[c137580 * 16 + c137583 * 1], r488[c137582 * 16 + c137583 * 1]);
        }
        /* convert [convert_element_type] -> r490 */
        for (long i13760 = 0; i13760 < 1; ++i13760) {
            r490[i13760] = (int32_t)r46[0];
        }
        /* max [max] -> r491 */
        for (long i13761 = 0; i13761 < 81920; ++i13761) {
            r491[i13761] = max32(r490[0], r489[i13761]);
        }
        /* convert [convert_element_type] -> r492 */
        for (long i13762 = 0; i13762 < 1; ++i13762) {
            r492[i13762] = (int32_t)r47[0];
        }
        /* min [min] -> r493 */
        for (long i13763 = 0; i13763 < 81920; ++i13763) {
            r493[i13763] = min32(r492[0], r491[i13763]);
        }
        /* sub [sub] -> r494 */
        for (long i13764 = 0; i13764 < 81920; ++i13764) {
            long t13766 = i13764;
            long c137650 = t13766 / 16384; t13766 %= 16384;
            long c137651 = t13766 / 16384; t13766 %= 16384;
            long c137652 = t13766 / 16; t13766 %= 16;
            long c137653 = t13766;
            r494[i13764] = sub32(r474[c137650 * 16 + c137653 * 1], r488[c137652 * 16 + c137653 * 1]);
        }
        /* convert [convert_element_type] -> r495 */
        for (long i13767 = 0; i13767 < 1; ++i13767) {
            r495[i13767] = (int32_t)r46[0];
        }
        /* max [max] -> r496 */
        for (long i13768 = 0; i13768 < 81920; ++i13768) {
            r496[i13768] = max32(r495[0], r494[i13768]);
        }
        /* convert [convert_element_type] -> r497 */
        for (long i13769 = 0; i13769 < 1; ++i13769) {
            r497[i13769] = (int32_t)r47[0];
        }
        /* min [min] -> r498 */
        for (long i13770 = 0; i13770 < 81920; ++i13770) {
            r498[i13770] = min32(r497[0], r496[i13770]);
        }
        /* abs [abs] -> r499 */
        for (long i13771 = 0; i13771 < 81920; ++i13771) {
            r499[i13771] = abs32(r493[i13771]);
        }
        /* reduce_max [reduce_max] -> r500 */
        for (long i13772 = 0; i13772 < 5120; ++i13772) {
            r500[i13772] = (-2147483647 - 1);
        }
        for (long i13773 = 0; i13773 < 81920; ++i13773) {
            long t13775 = i13773;
            long c137740 = t13775 / 16384; t13775 %= 16384;
            long c137741 = t13775 / 16384; t13775 %= 16384;
            long c137742 = t13775 / 16; t13775 %= 16;
            long c137743 = t13775;
            r500[c137740 * 1024 + c137741 * 1024 + c137742 * 1] = max32(r500[c137740 * 1024 + c137741 * 1024 + c137742 * 1], r499[i13773]);
        }
        /* sub [sub] -> r501 */
        for (long i13776 = 0; i13776 < 5120; ++i13776) {
            r501[i13776] = sub32(r500[i13776], r59[0]);
        }
        /* loop [scan] -> r523 */
        memcpy(r502, r493, sizeof(int32_t) * 81920);
        memcpy(r503, r59, sizeof(int32_t) * 1);
        memcpy(r504, r14, sizeof(int32_t) * 1);
        memcpy(r505, r501, sizeof(int32_t) * 5120);
        memcpy(r506, r500, sizeof(int32_t) * 5120);
        for (long t13777 = 0; t13777 < 12; ++t13777) {
            /* add [add] -> r507 */
            for (long i14778 = 0; i14778 < 1; ++i14778) {
                r507[i14778] = add32(r504[0], r9[0]);
            }
            /* add [add] -> r508 */
            for (long i14779 = 0; i14779 < 5120; ++i14779) {
                r508[i14779] = add32(r505[i14779], r506[i14779]);
            }
            /* shra [shift_right_arithmetic] -> r509 */
            for (long i14780 = 0; i14780 < 5120; ++i14780) {
                r509[i14780] = asr32(r508[i14780], 1);
            }
            /* broadcast [broadcast_in_dim] -> r510 */
            for (long i14781 = 0; i14781 < 5120; ++i14781) {
                long t14783 = i14781;
                long c147820 = t14783 / 1024; t14783 %= 1024;
                long c147821 = t14783 / 1024; t14783 %= 1024;
                long c147822 = t14783 / 1; t14783 %= 1;
                long c147823 = t14783;
                r510[i14781] = r509[c147820 * 1024 + c147822 * 1];
            }
            /* sub [sub] -> r511 */
            for (long i14784 = 0; i14784 < 81920; ++i14784) {
                long t14786 = i14784;
                long c147850 = t14786 / 16384; t14786 %= 16384;
                long c147851 = t14786 / 16384; t14786 %= 16384;
                long c147852 = t14786 / 16; t14786 %= 16;
                long c147853 = t14786;
                r511[i14784] = sub32(r502[c147850 * 16384 + c147852 * 16 + c147853 * 1], r510[c147850 * 1024 + c147852 * 1]);
            }
            /* max [max] -> r512 */
            for (long i14787 = 0; i14787 < 81920; ++i14787) {
                r512[i14787] = max32(r511[i14787], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r513 */
            for (long i14788 = 0; i14788 < 5120; ++i14788) {
                r513[i14788] = 0;
            }
            for (long i14789 = 0; i14789 < 81920; ++i14789) {
                long t14791 = i14789;
                long c147900 = t14791 / 16384; t14791 %= 16384;
                long c147901 = t14791 / 16384; t14791 %= 16384;
                long c147902 = t14791 / 16; t14791 %= 16;
                long c147903 = t14791;
                r513[c147900 * 1024 + c147901 * 1024 + c147902 * 1] = add32(r513[c147900 * 1024 + c147901 * 1024 + c147902 * 1], r512[i14789]);
            }
            /* neg [neg] -> r514 */
            for (long i14792 = 0; i14792 < 81920; ++i14792) {
                r514[i14792] = neg32(r502[i14792]);
            }
            /* broadcast [broadcast_in_dim] -> r515 */
            for (long i14793 = 0; i14793 < 5120; ++i14793) {
                long t14795 = i14793;
                long c147940 = t14795 / 1024; t14795 %= 1024;
                long c147941 = t14795 / 1024; t14795 %= 1024;
                long c147942 = t14795 / 1; t14795 %= 1;
                long c147943 = t14795;
                r515[i14793] = r509[c147940 * 1024 + c147942 * 1];
            }
            /* sub [sub] -> r516 */
            for (long i14796 = 0; i14796 < 81920; ++i14796) {
                long t14798 = i14796;
                long c147970 = t14798 / 16384; t14798 %= 16384;
                long c147971 = t14798 / 16384; t14798 %= 16384;
                long c147972 = t14798 / 16; t14798 %= 16;
                long c147973 = t14798;
                r516[i14796] = sub32(r514[c147970 * 16384 + c147972 * 16 + c147973 * 1], r515[c147970 * 1024 + c147972 * 1]);
            }
            /* max [max] -> r517 */
            for (long i14799 = 0; i14799 < 81920; ++i14799) {
                r517[i14799] = max32(r516[i14799], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r518 */
            for (long i14800 = 0; i14800 < 5120; ++i14800) {
                r518[i14800] = 0;
            }
            for (long i14801 = 0; i14801 < 81920; ++i14801) {
                long t14803 = i14801;
                long c148020 = t14803 / 16384; t14803 %= 16384;
                long c148021 = t14803 / 16384; t14803 %= 16384;
                long c148022 = t14803 / 16; t14803 %= 16;
                long c148023 = t14803;
                r518[c148020 * 1024 + c148021 * 1024 + c148022 * 1] = add32(r518[c148020 * 1024 + c148021 * 1024 + c148022 * 1], r517[i14801]);
            }
            /* add [add] -> r519 */
            for (long i14804 = 0; i14804 < 5120; ++i14804) {
                r519[i14804] = add32(r513[i14804], r518[i14804]);
            }
            /* gt [gt] -> r520 */
            for (long i14805 = 0; i14805 < 5120; ++i14805) {
                r520[i14805] = r519[i14805] > r503[0] ? 1 : 0;
            }
            /* select_n [select_n] -> r521 */
            for (long i14806 = 0; i14806 < 5120; ++i14806) {
                r521[i14806] = r520[i14806] == 0 ? r505[i14806] : (r509[i14806]);
            }
            /* select_n [select_n] -> r522 */
            for (long i14807 = 0; i14807 < 5120; ++i14807) {
                r522[i14807] = r520[i14807] == 0 ? r509[i14807] : (r506[i14807]);
            }
            memcpy(r504, r507, sizeof(int32_t) * 1);
            memcpy(r505, r521, sizeof(int32_t) * 5120);
            memcpy(r506, r522, sizeof(int32_t) * 5120);
        }
        memcpy(r523, r504, sizeof(int32_t) * 1);
        memcpy(r524, r505, sizeof(int32_t) * 5120);
        memcpy(r525, r506, sizeof(int32_t) * 5120);
        /* abs [abs] -> r526 */
        for (long i14808 = 0; i14808 < 81920; ++i14808) {
            r526[i14808] = abs32(r498[i14808]);
        }
        /* reduce_max [reduce_max] -> r527 */
        for (long i14809 = 0; i14809 < 5120; ++i14809) {
            r527[i14809] = (-2147483647 - 1);
        }
        for (long i14810 = 0; i14810 < 81920; ++i14810) {
            long t14812 = i14810;
            long c148110 = t14812 / 16384; t14812 %= 16384;
            long c148111 = t14812 / 16384; t14812 %= 16384;
            long c148112 = t14812 / 16; t14812 %= 16;
            long c148113 = t14812;
            r527[c148110 * 1024 + c148111 * 1024 + c148112 * 1] = max32(r527[c148110 * 1024 + c148111 * 1024 + c148112 * 1], r526[i14810]);
        }
        /* sub [sub] -> r528 */
        for (long i14813 = 0; i14813 < 5120; ++i14813) {
            r528[i14813] = sub32(r527[i14813], r59[0]);
        }
        /* loop [scan] -> r550 */
        memcpy(r529, r498, sizeof(int32_t) * 81920);
        memcpy(r530, r59, sizeof(int32_t) * 1);
        memcpy(r531, r14, sizeof(int32_t) * 1);
        memcpy(r532, r528, sizeof(int32_t) * 5120);
        memcpy(r533, r527, sizeof(int32_t) * 5120);
        for (long t14814 = 0; t14814 < 12; ++t14814) {
            /* add [add] -> r534 */
            for (long i15815 = 0; i15815 < 1; ++i15815) {
                r534[i15815] = add32(r531[0], r9[0]);
            }
            /* add [add] -> r535 */
            for (long i15816 = 0; i15816 < 5120; ++i15816) {
                r535[i15816] = add32(r532[i15816], r533[i15816]);
            }
            /* shra [shift_right_arithmetic] -> r536 */
            for (long i15817 = 0; i15817 < 5120; ++i15817) {
                r536[i15817] = asr32(r535[i15817], 1);
            }
            /* broadcast [broadcast_in_dim] -> r537 */
            for (long i15818 = 0; i15818 < 5120; ++i15818) {
                long t15820 = i15818;
                long c158190 = t15820 / 1024; t15820 %= 1024;
                long c158191 = t15820 / 1024; t15820 %= 1024;
                long c158192 = t15820 / 1; t15820 %= 1;
                long c158193 = t15820;
                r537[i15818] = r536[c158190 * 1024 + c158192 * 1];
            }
            /* sub [sub] -> r538 */
            for (long i15821 = 0; i15821 < 81920; ++i15821) {
                long t15823 = i15821;
                long c158220 = t15823 / 16384; t15823 %= 16384;
                long c158221 = t15823 / 16384; t15823 %= 16384;
                long c158222 = t15823 / 16; t15823 %= 16;
                long c158223 = t15823;
                r538[i15821] = sub32(r529[c158220 * 16384 + c158222 * 16 + c158223 * 1], r537[c158220 * 1024 + c158222 * 1]);
            }
            /* max [max] -> r539 */
            for (long i15824 = 0; i15824 < 81920; ++i15824) {
                r539[i15824] = max32(r538[i15824], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r540 */
            for (long i15825 = 0; i15825 < 5120; ++i15825) {
                r540[i15825] = 0;
            }
            for (long i15826 = 0; i15826 < 81920; ++i15826) {
                long t15828 = i15826;
                long c158270 = t15828 / 16384; t15828 %= 16384;
                long c158271 = t15828 / 16384; t15828 %= 16384;
                long c158272 = t15828 / 16; t15828 %= 16;
                long c158273 = t15828;
                r540[c158270 * 1024 + c158271 * 1024 + c158272 * 1] = add32(r540[c158270 * 1024 + c158271 * 1024 + c158272 * 1], r539[i15826]);
            }
            /* neg [neg] -> r541 */
            for (long i15829 = 0; i15829 < 81920; ++i15829) {
                r541[i15829] = neg32(r529[i15829]);
            }
            /* broadcast [broadcast_in_dim] -> r542 */
            for (long i15830 = 0; i15830 < 5120; ++i15830) {
                long t15832 = i15830;
                long c158310 = t15832 / 1024; t15832 %= 1024;
                long c158311 = t15832 / 1024; t15832 %= 1024;
                long c158312 = t15832 / 1; t15832 %= 1;
                long c158313 = t15832;
                r542[i15830] = r536[c158310 * 1024 + c158312 * 1];
            }
            /* sub [sub] -> r543 */
            for (long i15833 = 0; i15833 < 81920; ++i15833) {
                long t15835 = i15833;
                long c158340 = t15835 / 16384; t15835 %= 16384;
                long c158341 = t15835 / 16384; t15835 %= 16384;
                long c158342 = t15835 / 16; t15835 %= 16;
                long c158343 = t15835;
                r543[i15833] = sub32(r541[c158340 * 16384 + c158342 * 16 + c158343 * 1], r542[c158340 * 1024 + c158342 * 1]);
            }
            /* max [max] -> r544 */
            for (long i15836 = 0; i15836 < 81920; ++i15836) {
                r544[i15836] = max32(r543[i15836], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r545 */
            for (long i15837 = 0; i15837 < 5120; ++i15837) {
                r545[i15837] = 0;
            }
            for (long i15838 = 0; i15838 < 81920; ++i15838) {
                long t15840 = i15838;
                long c158390 = t15840 / 16384; t15840 %= 16384;
                long c158391 = t15840 / 16384; t15840 %= 16384;
                long c158392 = t15840 / 16; t15840 %= 16;
                long c158393 = t15840;
                r545[c158390 * 1024 + c158391 * 1024 + c158392 * 1] = add32(r545[c158390 * 1024 + c158391 * 1024 + c158392 * 1], r544[i15838]);
            }
            /* add [add] -> r546 */
            for (long i15841 = 0; i15841 < 5120; ++i15841) {
                r546[i15841] = add32(r540[i15841], r545[i15841]);
            }
            /* gt [gt] -> r547 */
            for (long i15842 = 0; i15842 < 5120; ++i15842) {
                r547[i15842] = r546[i15842] > r530[0] ? 1 : 0;
            }
            /* select_n [select_n] -> r548 */
            for (long i15843 = 0; i15843 < 5120; ++i15843) {
                r548[i15843] = r547[i15843] == 0 ? r532[i15843] : (r536[i15843]);
            }
            /* select_n [select_n] -> r549 */
            for (long i15844 = 0; i15844 < 5120; ++i15844) {
                r549[i15844] = r547[i15844] == 0 ? r536[i15844] : (r533[i15844]);
            }
            memcpy(r531, r534, sizeof(int32_t) * 1);
            memcpy(r532, r548, sizeof(int32_t) * 5120);
            memcpy(r533, r549, sizeof(int32_t) * 5120);
        }
        memcpy(r550, r531, sizeof(int32_t) * 1);
        memcpy(r551, r532, sizeof(int32_t) * 5120);
        memcpy(r552, r533, sizeof(int32_t) * 5120);
        /* sub [sub] -> r553 */
        for (long i15845 = 0; i15845 < 5120; ++i15845) {
            r553[i15845] = sub32(r525[i15845], r552[i15845]);
        }
        memcpy(r554 + t12732 * 5120, r553, sizeof(int32_t) * 5120);
    }
    /* transpose [transpose] -> r555 */
    for (long i15846 = 0; i15846 < 20480; ++i15846) {
        long t15848 = i15846;
        long c158470 = t15848 / 4096; t15848 %= 4096;
        long c158471 = t15848 / 4096; t15848 %= 4096;
        long c158472 = t15848 / 1024; t15848 %= 1024;
        long c158473 = t15848;
        r555[i15846] = r554[c158470 * 1024 + c158471 * 1024 + c158472 * 5120 + c158473 * 1];
    }
    /* reshape [reshape] -> r556 */
    memcpy(r556, r555, sizeof(int32_t) * 20480);
    /* slice [slice] -> r557 */
    for (long i15849 = 0; i15849 < 20000; ++i15849) {
        long t15851 = i15849;
        long c158500 = t15851 / 4000; t15851 %= 4000;
        long c158501 = t15851 / 4000; t15851 %= 4000;
        long c158502 = t15851;
        r557[i15849] = r556[(0 + c158500 * 1) * 4096 + (0 + c158501 * 1) * 4096 + (0 + c158502 * 1) * 1];
    }
    /* transpose [transpose] -> r558 */
    for (long i15852 = 0; i15852 < 20000; ++i15852) {
        long t15854 = i15852;
        long c158530 = t15854 / 20000; t15854 %= 20000;
        long c158531 = t15854 / 4000; t15854 %= 4000;
        long c158532 = t15854;
        r558[i15852] = r557[c158530 * 4000 + c158531 * 4000 + c158532 * 1];
    }
    /* max [max] -> r559 */
    for (long i15855 = 0; i15855 < 20000; ++i15855) {
        r559[i15855] = max32(r558[i15855], r14[0]);
    }
    /* reduce_sum [reduce_sum] -> r560 */
    for (long i15856 = 0; i15856 < 5; ++i15856) {
        r560[i15856] = 0;
    }
    for (long i15857 = 0; i15857 < 20000; ++i15857) {
        long t15859 = i15857;
        long c158580 = t15859 / 20000; t15859 %= 20000;
        long c158581 = t15859 / 4000; t15859 %= 4000;
        long c158582 = t15859;
        r560[c158580 * 5 + c158581 * 1] = add32(r560[c158580 * 5 + c158581 * 1], r559[i15857]);
    }
    /* shl [shift_left] -> r562 */
    for (long i15860 = 0; i15860 < 5; ++i15860) {
        r562[i15860] = shl32(r560[i15860], 2);
    }
    /* shl [shift_left] -> r563 */
    for (long i15861 = 0; i15861 < 4000; ++i15861) {
        r563[i15861] = shl32(r456[i15861], 1);
    }
    /* mov [device_put] -> r564 */
    memcpy(r564, r2, sizeof(int32_t) * 6);
    /* rev [rev] -> r565 */
    for (long i15862 = 0; i15862 < 6; ++i15862) {
        long t15864 = i15862;
        long c158630 = t15864 / 6; t15864 %= 6;
        long c158631 = t15864;
        r565[i15862] = r564[c158630 * 6 + (6 - 1 - c158631) * 1];
    }
    /* reshape [reshape] -> r566 */
    memcpy(r566, r565, sizeof(int32_t) * 6);
    /* convert [convert_element_type] -> r567 */
    for (long i15865 = 0; i15865 < 1; ++i15865) {
        r567[i15865] = (int32_t)r14[0];
    }
    /* pad [pad] -> r568 */
    for (long i15866 = 0; i15866 < 4005; ++i15866) {
        r568[i15866] = r567[0];
    }
    for (long i15867 = 0; i15867 < 4000; ++i15867) {
        long t15869 = i15867;
        long c158680 = t15869 / 4000; t15869 %= 4000;
        long c158681 = t15869;
        long d15870 = 0 + c158680 * 1;
        long d15871 = 5 + c158681 * 1;
        if (d15870 >= 0 && d15870 < 1 && d15871 >= 0 && d15871 < 4005) r568[d15870 * 4005 + d15871 * 1] = r563[i15867];
    }
    /* convert [convert_element_type] -> r569 */
    for (long i15872 = 0; i15872 < 1; ++i15872) {
        r569[i15872] = (int32_t)r14[0];
    }
    /* pad [pad] -> r570 */
    for (long i15873 = 0; i15873 < 4101; ++i15873) {
        r570[i15873] = r569[0];
    }
    for (long i15874 = 0; i15874 < 4005; ++i15874) {
        long t15876 = i15874;
        long c158750 = t15876 / 4005; t15876 %= 4005;
        long c158751 = t15876;
        long d15877 = 0 + c158750 * 1;
        long d15878 = 0 + c158751 * 1;
        if (d15877 >= 0 && d15877 < 1 && d15878 >= 0 && d15878 < 4101) r570[d15877 * 4101 + d15878 * 1] = r568[i15874];
    }
    /* iota [iota] -> r571 */
    for (long i15879 = 0; i15879 < 1024; ++i15879) {
        long t15881 = i15879;
        long c158800 = t15881;
        r571[i15879] = (int32_t)c158800;
    }
    /* broadcast [broadcast_in_dim] -> r572 */
    for (long i15882 = 0; i15882 < 1024; ++i15882) {
        long t15884 = i15882;
        long c158830 = t15884 / 1; t15884 %= 1;
        long c158831 = t15884;
        r572[i15882] = r571[c158830 * 1];
    }
    /* iota [iota] -> r573 */
    for (long i15885 = 0; i15885 < 6; ++i15885) {
        long t15887 = i15885;
        long c158860 = t15887;
        r573[i15885] = (int32_t)c158860;
    }
    /* broadcast [broadcast_in_dim] -> r574 */
    for (long i15888 = 0; i15888 < 6; ++i15888) {
        long t15890 = i15888;
        long c158890 = t15890 / 6; t15890 %= 6;
        long c158891 = t15890;
        r574[i15888] = r573[c158891 * 1];
    }
    /* add [add] -> r575 */
    for (long i15891 = 0; i15891 < 6144; ++i15891) {
        long t15893 = i15891;
        long c158920 = t15893 / 6; t15893 %= 6;
        long c158921 = t15893;
        r575[i15891] = add32(r572[c158920 * 1], r574[c158921 * 1]);
    }
    /* iota [iota] -> r576 */
    for (long i15894 = 0; i15894 < 4; ++i15894) {
        long t15896 = i15894;
        long c158950 = t15896;
        r576[i15894] = (int32_t)c158950;
    }
    /* shl [mul] -> r577 */
    for (long i15897 = 0; i15897 < 4; ++i15897) {
        r577[i15897] = shl32(r576[i15897], 10);
    }
    /* loop [scan] -> r660 */
    memcpy(r578, r570, sizeof(int32_t) * 4101);
    memcpy(r579, r575, sizeof(int32_t) * 6144);
    memcpy(r580, r566, sizeof(int32_t) * 6);
    for (long t15898 = 0; t15898 < 4; ++t15898) {
        memcpy(r581, r577 + t15898 * 1, sizeof(int32_t) * 1);
        /* add [add] -> r582 */
        for (long i16899 = 0; i16899 < 1; ++i16899) {
            r582[i16899] = add32(r14[0], r9[0]);
        }
        /* select_n [select_n] -> r583 */
        for (long i16900 = 0; i16900 < 1; ++i16900) {
            r583[i16900] = r31[0] == 0 ? r14[0] : (r582[0]);
        }
        /* lt [lt] -> r584 */
        for (long i16901 = 0; i16901 < 1; ++i16901) {
            r584[i16901] = r581[0] < r14[0] ? 1 : 0;
        }
        /* add [add] -> r586 */
        for (long i16902 = 0; i16902 < 1; ++i16902) {
            r586[i16902] = add32(r581[0], r585[0]);
        }
        /* select_n [select_n] -> r587 */
        for (long i16903 = 0; i16903 < 1; ++i16903) {
            r587[i16903] = r584[0] == 0 ? r581[0] : (r586[0]);
        }
        /* dynamic_slice [dynamic_slice] -> r588 */
        long s16904 = clamp_start((long)r583[0], 1, 1);
        long s16905 = clamp_start((long)r587[0], 4101, 1029);
        {
        for (long i16906 = 0; i16906 < 1029; ++i16906) {
            long t16908 = i16906;
            long c169070 = t16908 / 1029; t16908 %= 1029;
            long c169071 = t16908;
            r588[i16906] = r578[(s16904 + c169070) * 4101 + (s16905 + c169071) * 1];
        }
        }
        /* lt [lt] -> r589 */
        for (long i16909 = 0; i16909 < 6144; ++i16909) {
            r589[i16909] = r579[i16909] < r14[0] ? 1 : 0;
        }
        /* add [add] -> r590 */
        for (long i16910 = 0; i16910 < 6144; ++i16910) {
            r590[i16910] = add32(r579[i16910], r148[0]);
        }
        /* select_n [select_n] -> r591 */
        for (long i16911 = 0; i16911 < 6144; ++i16911) {
            r591[i16911] = r589[i16911] == 0 ? r579[i16911] : (r590[i16911]);
        }
        /* broadcast [broadcast_in_dim] -> r592 */
        for (long i16912 = 0; i16912 < 6144; ++i16912) {
            long t16914 = i16912;
            long c169130 = t16914 / 6; t16914 %= 6;
            long c169131 = t16914 / 1; t16914 %= 1;
            long c169132 = t16914;
            r592[i16912] = r591[c169130 * 6 + c169131 * 1];
        }
        /* gather [gather] -> r593 */
        for (long i16915 = 0; i16915 < 6144; ++i16915) {
            long t16917 = i16915;
            long c169160 = t16917 / 6144; t16917 %= 6144;
            long c169161 = t16917 / 6; t16917 %= 6;
            long c169162 = t16917;
            long row16918 = c169161 * 6 + c169162 * 1;
            long s16919 = clamp_start((long)r592[row16918 + 0], 1029, 1);
            r593[i16915] = r588[c169160 * 1029 + s16919 * 1];
        }
        /* broadcast [broadcast_in_dim] -> r594 */
        for (long i16920 = 0; i16920 < 6144; ++i16920) {
            long t16922 = i16920;
            long c169210 = t16922 / 6144; t16922 %= 6144;
            long c169211 = t16922 / 6144; t16922 %= 6144;
            long c169212 = t16922 / 6; t16922 %= 6;
            long c169213 = t16922;
            r594[i16920] = r593[c169212 * 6 + c169213 * 1];
        }
        /* add [add] -> r595 */
        for (long i16923 = 0; i16923 < 6144; ++i16923) {
            long t16925 = i16923;
            long c169240 = t16925 / 6144; t16925 %= 6144;
            long c169241 = t16925 / 6144; t16925 %= 6144;
            long c169242 = t16925 / 6; t16925 %= 6;
            long c169243 = t16925;
            r595[i16923] = add32(r580[c169243 * 1], r594[c169242 * 6 + c169243 * 1]);
        }
        /* convert [convert_element_type] -> r596 */
        for (long i16926 = 0; i16926 < 1; ++i16926) {
            r596[i16926] = (int32_t)r46[0];
        }
        /* max [max] -> r597 */
        for (long i16927 = 0; i16927 < 6144; ++i16927) {
            r597[i16927] = max32(r596[0], r595[i16927]);
        }
        /* convert [convert_element_type] -> r598 */
        for (long i16928 = 0; i16928 < 1; ++i16928) {
            r598[i16928] = (int32_t)r47[0];
        }
        /* min [min] -> r599 */
        for (long i16929 = 0; i16929 < 6144; ++i16929) {
            r599[i16929] = min32(r598[0], r597[i16929]);
        }
        /* sub [sub] -> r600 */
        for (long i16930 = 0; i16930 < 6144; ++i16930) {
            long t16932 = i16930;
            long c169310 = t16932 / 6144; t16932 %= 6144;
            long c169311 = t16932 / 6144; t16932 %= 6144;
            long c169312 = t16932 / 6; t16932 %= 6;
            long c169313 = t16932;
            r600[i16930] = sub32(r580[c169313 * 1], r594[c169312 * 6 + c169313 * 1]);
        }
        /* convert [convert_element_type] -> r601 */
        for (long i16933 = 0; i16933 < 1; ++i16933) {
            r601[i16933] = (int32_t)r46[0];
        }
        /* max [max] -> r602 */
        for (long i16934 = 0; i16934 < 6144; ++i16934) {
            r602[i16934] = max32(r601[0], r600[i16934]);
        }
        /* convert [convert_element_type] -> r603 */
        for (long i16935 = 0; i16935 < 1; ++i16935) {
            r603[i16935] = (int32_t)r47[0];
        }
        /* min [min] -> r604 */
        for (long i16936 = 0; i16936 < 6144; ++i16936) {
            r604[i16936] = min32(r603[0], r602[i16936]);
        }
        /* abs [abs] -> r605 */
        for (long i16937 = 0; i16937 < 6144; ++i16937) {
            r605[i16937] = abs32(r599[i16937]);
        }
        /* reduce_max [reduce_max] -> r606 */
        for (long i16938 = 0; i16938 < 1024; ++i16938) {
            r606[i16938] = (-2147483647 - 1);
        }
        for (long i16939 = 0; i16939 < 6144; ++i16939) {
            long t16941 = i16939;
            long c169400 = t16941 / 6144; t16941 %= 6144;
            long c169401 = t16941 / 6144; t16941 %= 6144;
            long c169402 = t16941 / 6; t16941 %= 6;
            long c169403 = t16941;
            r606[c169400 * 1024 + c169401 * 1024 + c169402 * 1] = max32(r606[c169400 * 1024 + c169401 * 1024 + c169402 * 1], r605[i16939]);
        }
        /* sub [sub] -> r607 */
        for (long i16942 = 0; i16942 < 1024; ++i16942) {
            r607[i16942] = sub32(r606[i16942], r59[0]);
        }
        /* loop [scan] -> r629 */
        memcpy(r608, r599, sizeof(int32_t) * 6144);
        memcpy(r609, r59, sizeof(int32_t) * 1);
        memcpy(r610, r14, sizeof(int32_t) * 1);
        memcpy(r611, r607, sizeof(int32_t) * 1024);
        memcpy(r612, r606, sizeof(int32_t) * 1024);
        for (long t16943 = 0; t16943 < 12; ++t16943) {
            /* add [add] -> r613 */
            for (long i17944 = 0; i17944 < 1; ++i17944) {
                r613[i17944] = add32(r610[0], r9[0]);
            }
            /* add [add] -> r614 */
            for (long i17945 = 0; i17945 < 1024; ++i17945) {
                r614[i17945] = add32(r611[i17945], r612[i17945]);
            }
            /* shra [shift_right_arithmetic] -> r615 */
            for (long i17946 = 0; i17946 < 1024; ++i17946) {
                r615[i17946] = asr32(r614[i17946], 1);
            }
            /* broadcast [broadcast_in_dim] -> r616 */
            for (long i17947 = 0; i17947 < 1024; ++i17947) {
                long t17949 = i17947;
                long c179480 = t17949 / 1024; t17949 %= 1024;
                long c179481 = t17949 / 1024; t17949 %= 1024;
                long c179482 = t17949 / 1; t17949 %= 1;
                long c179483 = t17949;
                r616[i17947] = r615[c179482 * 1];
            }
            /* sub [sub] -> r617 */
            for (long i17950 = 0; i17950 < 6144; ++i17950) {
                long t17952 = i17950;
                long c179510 = t17952 / 6144; t17952 %= 6144;
                long c179511 = t17952 / 6144; t17952 %= 6144;
                long c179512 = t17952 / 6; t17952 %= 6;
                long c179513 = t17952;
                r617[i17950] = sub32(r608[c179512 * 6 + c179513 * 1], r616[c179512 * 1]);
            }
            /* max [max] -> r618 */
            for (long i17953 = 0; i17953 < 6144; ++i17953) {
                r618[i17953] = max32(r617[i17953], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r619 */
            for (long i17954 = 0; i17954 < 1024; ++i17954) {
                r619[i17954] = 0;
            }
            for (long i17955 = 0; i17955 < 6144; ++i17955) {
                long t17957 = i17955;
                long c179560 = t17957 / 6144; t17957 %= 6144;
                long c179561 = t17957 / 6144; t17957 %= 6144;
                long c179562 = t17957 / 6; t17957 %= 6;
                long c179563 = t17957;
                r619[c179560 * 1024 + c179561 * 1024 + c179562 * 1] = add32(r619[c179560 * 1024 + c179561 * 1024 + c179562 * 1], r618[i17955]);
            }
            /* neg [neg] -> r620 */
            for (long i17958 = 0; i17958 < 6144; ++i17958) {
                r620[i17958] = neg32(r608[i17958]);
            }
            /* broadcast [broadcast_in_dim] -> r621 */
            for (long i17959 = 0; i17959 < 1024; ++i17959) {
                long t17961 = i17959;
                long c179600 = t17961 / 1024; t17961 %= 1024;
                long c179601 = t17961 / 1024; t17961 %= 1024;
                long c179602 = t17961 / 1; t17961 %= 1;
                long c179603 = t17961;
                r621[i17959] = r615[c179602 * 1];
            }
            /* sub [sub] -> r622 */
            for (long i17962 = 0; i17962 < 6144; ++i17962) {
                long t17964 = i17962;
                long c179630 = t17964 / 6144; t17964 %= 6144;
                long c179631 = t17964 / 6144; t17964 %= 6144;
                long c179632 = t17964 / 6; t17964 %= 6;
                long c179633 = t17964;
                r622[i17962] = sub32(r620[c179632 * 6 + c179633 * 1], r621[c179632 * 1]);
            }
            /* max [max] -> r623 */
            for (long i17965 = 0; i17965 < 6144; ++i17965) {
                r623[i17965] = max32(r622[i17965], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r624 */
            for (long i17966 = 0; i17966 < 1024; ++i17966) {
                r624[i17966] = 0;
            }
            for (long i17967 = 0; i17967 < 6144; ++i17967) {
                long t17969 = i17967;
                long c179680 = t17969 / 6144; t17969 %= 6144;
                long c179681 = t17969 / 6144; t17969 %= 6144;
                long c179682 = t17969 / 6; t17969 %= 6;
                long c179683 = t17969;
                r624[c179680 * 1024 + c179681 * 1024 + c179682 * 1] = add32(r624[c179680 * 1024 + c179681 * 1024 + c179682 * 1], r623[i17967]);
            }
            /* add [add] -> r625 */
            for (long i17970 = 0; i17970 < 1024; ++i17970) {
                r625[i17970] = add32(r619[i17970], r624[i17970]);
            }
            /* gt [gt] -> r626 */
            for (long i17971 = 0; i17971 < 1024; ++i17971) {
                r626[i17971] = r625[i17971] > r609[0] ? 1 : 0;
            }
            /* select_n [select_n] -> r627 */
            for (long i17972 = 0; i17972 < 1024; ++i17972) {
                r627[i17972] = r626[i17972] == 0 ? r611[i17972] : (r615[i17972]);
            }
            /* select_n [select_n] -> r628 */
            for (long i17973 = 0; i17973 < 1024; ++i17973) {
                r628[i17973] = r626[i17973] == 0 ? r615[i17973] : (r612[i17973]);
            }
            memcpy(r610, r613, sizeof(int32_t) * 1);
            memcpy(r611, r627, sizeof(int32_t) * 1024);
            memcpy(r612, r628, sizeof(int32_t) * 1024);
        }
        memcpy(r629, r610, sizeof(int32_t) * 1);
        memcpy(r630, r611, sizeof(int32_t) * 1024);
        memcpy(r631, r612, sizeof(int32_t) * 1024);
        /* abs [abs] -> r632 */
        for (long i17974 = 0; i17974 < 6144; ++i17974) {
            r632[i17974] = abs32(r604[i17974]);
        }
        /* reduce_max [reduce_max] -> r633 */
        for (long i17975 = 0; i17975 < 1024; ++i17975) {
            r633[i17975] = (-2147483647 - 1);
        }
        for (long i17976 = 0; i17976 < 6144; ++i17976) {
            long t17978 = i17976;
            long c179770 = t17978 / 6144; t17978 %= 6144;
            long c179771 = t17978 / 6144; t17978 %= 6144;
            long c179772 = t17978 / 6; t17978 %= 6;
            long c179773 = t17978;
            r633[c179770 * 1024 + c179771 * 1024 + c179772 * 1] = max32(r633[c179770 * 1024 + c179771 * 1024 + c179772 * 1], r632[i17976]);
        }
        /* sub [sub] -> r634 */
        for (long i17979 = 0; i17979 < 1024; ++i17979) {
            r634[i17979] = sub32(r633[i17979], r59[0]);
        }
        /* loop [scan] -> r656 */
        memcpy(r635, r604, sizeof(int32_t) * 6144);
        memcpy(r636, r59, sizeof(int32_t) * 1);
        memcpy(r637, r14, sizeof(int32_t) * 1);
        memcpy(r638, r634, sizeof(int32_t) * 1024);
        memcpy(r639, r633, sizeof(int32_t) * 1024);
        for (long t17980 = 0; t17980 < 12; ++t17980) {
            /* add [add] -> r640 */
            for (long i18981 = 0; i18981 < 1; ++i18981) {
                r640[i18981] = add32(r637[0], r9[0]);
            }
            /* add [add] -> r641 */
            for (long i18982 = 0; i18982 < 1024; ++i18982) {
                r641[i18982] = add32(r638[i18982], r639[i18982]);
            }
            /* shra [shift_right_arithmetic] -> r642 */
            for (long i18983 = 0; i18983 < 1024; ++i18983) {
                r642[i18983] = asr32(r641[i18983], 1);
            }
            /* broadcast [broadcast_in_dim] -> r643 */
            for (long i18984 = 0; i18984 < 1024; ++i18984) {
                long t18986 = i18984;
                long c189850 = t18986 / 1024; t18986 %= 1024;
                long c189851 = t18986 / 1024; t18986 %= 1024;
                long c189852 = t18986 / 1; t18986 %= 1;
                long c189853 = t18986;
                r643[i18984] = r642[c189852 * 1];
            }
            /* sub [sub] -> r644 */
            for (long i18987 = 0; i18987 < 6144; ++i18987) {
                long t18989 = i18987;
                long c189880 = t18989 / 6144; t18989 %= 6144;
                long c189881 = t18989 / 6144; t18989 %= 6144;
                long c189882 = t18989 / 6; t18989 %= 6;
                long c189883 = t18989;
                r644[i18987] = sub32(r635[c189882 * 6 + c189883 * 1], r643[c189882 * 1]);
            }
            /* max [max] -> r645 */
            for (long i18990 = 0; i18990 < 6144; ++i18990) {
                r645[i18990] = max32(r644[i18990], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r646 */
            for (long i18991 = 0; i18991 < 1024; ++i18991) {
                r646[i18991] = 0;
            }
            for (long i18992 = 0; i18992 < 6144; ++i18992) {
                long t18994 = i18992;
                long c189930 = t18994 / 6144; t18994 %= 6144;
                long c189931 = t18994 / 6144; t18994 %= 6144;
                long c189932 = t18994 / 6; t18994 %= 6;
                long c189933 = t18994;
                r646[c189930 * 1024 + c189931 * 1024 + c189932 * 1] = add32(r646[c189930 * 1024 + c189931 * 1024 + c189932 * 1], r645[i18992]);
            }
            /* neg [neg] -> r647 */
            for (long i18995 = 0; i18995 < 6144; ++i18995) {
                r647[i18995] = neg32(r635[i18995]);
            }
            /* broadcast [broadcast_in_dim] -> r648 */
            for (long i18996 = 0; i18996 < 1024; ++i18996) {
                long t18998 = i18996;
                long c189970 = t18998 / 1024; t18998 %= 1024;
                long c189971 = t18998 / 1024; t18998 %= 1024;
                long c189972 = t18998 / 1; t18998 %= 1;
                long c189973 = t18998;
                r648[i18996] = r642[c189972 * 1];
            }
            /* sub [sub] -> r649 */
            for (long i18999 = 0; i18999 < 6144; ++i18999) {
                long t19001 = i18999;
                long c190000 = t19001 / 6144; t19001 %= 6144;
                long c190001 = t19001 / 6144; t19001 %= 6144;
                long c190002 = t19001 / 6; t19001 %= 6;
                long c190003 = t19001;
                r649[i18999] = sub32(r647[c190002 * 6 + c190003 * 1], r648[c190002 * 1]);
            }
            /* max [max] -> r650 */
            for (long i19002 = 0; i19002 < 6144; ++i19002) {
                r650[i19002] = max32(r649[i19002], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r651 */
            for (long i19003 = 0; i19003 < 1024; ++i19003) {
                r651[i19003] = 0;
            }
            for (long i19004 = 0; i19004 < 6144; ++i19004) {
                long t19006 = i19004;
                long c190050 = t19006 / 6144; t19006 %= 6144;
                long c190051 = t19006 / 6144; t19006 %= 6144;
                long c190052 = t19006 / 6; t19006 %= 6;
                long c190053 = t19006;
                r651[c190050 * 1024 + c190051 * 1024 + c190052 * 1] = add32(r651[c190050 * 1024 + c190051 * 1024 + c190052 * 1], r650[i19004]);
            }
            /* add [add] -> r652 */
            for (long i19007 = 0; i19007 < 1024; ++i19007) {
                r652[i19007] = add32(r646[i19007], r651[i19007]);
            }
            /* gt [gt] -> r653 */
            for (long i19008 = 0; i19008 < 1024; ++i19008) {
                r653[i19008] = r652[i19008] > r636[0] ? 1 : 0;
            }
            /* select_n [select_n] -> r654 */
            for (long i19009 = 0; i19009 < 1024; ++i19009) {
                r654[i19009] = r653[i19009] == 0 ? r638[i19009] : (r642[i19009]);
            }
            /* select_n [select_n] -> r655 */
            for (long i19010 = 0; i19010 < 1024; ++i19010) {
                r655[i19010] = r653[i19010] == 0 ? r642[i19010] : (r639[i19010]);
            }
            memcpy(r637, r640, sizeof(int32_t) * 1);
            memcpy(r638, r654, sizeof(int32_t) * 1024);
            memcpy(r639, r655, sizeof(int32_t) * 1024);
        }
        memcpy(r656, r637, sizeof(int32_t) * 1);
        memcpy(r657, r638, sizeof(int32_t) * 1024);
        memcpy(r658, r639, sizeof(int32_t) * 1024);
        /* sub [sub] -> r659 */
        for (long i19011 = 0; i19011 < 1024; ++i19011) {
            r659[i19011] = sub32(r631[i19011], r658[i19011]);
        }
        memcpy(r660 + t15898 * 1024, r659, sizeof(int32_t) * 1024);
    }
    /* transpose [transpose] -> r661 */
    for (long i19012 = 0; i19012 < 4096; ++i19012) {
        long t19014 = i19012;
        long c190130 = t19014 / 4096; t19014 %= 4096;
        long c190131 = t19014 / 4096; t19014 %= 4096;
        long c190132 = t19014 / 1024; t19014 %= 1024;
        long c190133 = t19014;
        r661[i19012] = r660[c190130 * 1024 + c190131 * 1024 + c190132 * 1024 + c190133 * 1];
    }
    /* reshape [reshape] -> r662 */
    memcpy(r662, r661, sizeof(int32_t) * 4096);
    /* slice [slice] -> r663 */
    for (long i19015 = 0; i19015 < 4000; ++i19015) {
        long t19017 = i19015;
        long c190160 = t19017 / 4000; t19017 %= 4000;
        long c190161 = t19017 / 4000; t19017 %= 4000;
        long c190162 = t19017;
        r663[i19015] = r662[(0 + c190160 * 1) * 4096 + (0 + c190161 * 1) * 4096 + (0 + c190162 * 1) * 1];
    }
    /* transpose [transpose] -> r664 */
    for (long i19018 = 0; i19018 < 4000; ++i19018) {
        long t19020 = i19018;
        long c190190 = t19020 / 4000; t19020 %= 4000;
        long c190191 = t19020 / 4000; t19020 %= 4000;
        long c190192 = t19020;
        r664[i19018] = r663[c190190 * 4000 + c190191 * 4000 + c190192 * 1];
    }
    /* slice [slice] -> r665 */
    for (long i19021 = 0; i19021 < 4000; ++i19021) {
        long t19023 = i19021;
        long c190220 = t19023 / 4000; t19023 %= 4000;
        long c190221 = t19023 / 4000; t19023 %= 4000;
        long c190222 = t19023;
        r665[i19021] = r664[(0 + c190220 * 1) * 4000 + (0 + c190221 * 1) * 4000 + (0 + c190222 * 1) * 1];
    }
    /* reshape [squeeze] -> r666 */
    memcpy(r666, r665, sizeof(int32_t) * 4000);
    /* shra [shift_right_arithmetic] -> r667 */
    for (long i19024 = 0; i19024 < 4000; ++i19024) {
        r667[i19024] = asr32(r666[i19024], 1);
    }
    /* convert [convert_element_type] -> r668 */
    for (long i19025 = 0; i19025 < 1; ++i19025) {
        r668[i19025] = (int32_t)r227[0];
    }
    /* max [max] -> r669 */
    for (long i19026 = 0; i19026 < 4000; ++i19026) {
        r669[i19026] = max32(r668[0], r667[i19026]);
    }
    /* convert [convert_element_type] -> r670 */
    for (long i19027 = 0; i19027 < 1; ++i19027) {
        r670[i19027] = (int32_t)r228[0];
    }
    /* min [min] -> r671 */
    for (long i19028 = 0; i19028 < 4000; ++i19028) {
        r671[i19028] = min32(r670[0], r669[i19028]);
    }
    /* iota [iota] -> r672 */
    for (long i19029 = 0; i19029 < 2000; ++i19029) {
        long t19031 = i19029;
        long c190300 = t19031;
        r672[i19029] = (int32_t)c190300;
    }
    /* shl [mul] -> r673 */
    for (long i19032 = 0; i19032 < 2000; ++i19032) {
        r673[i19032] = shl32(r672[i19032], 1);
    }
    /* add [add] -> r674 */
    for (long i19033 = 0; i19033 < 2000; ++i19033) {
        r674[i19033] = add32(r14[0], r673[i19033]);
    }
    /* broadcast [broadcast_in_dim] -> r675 */
    for (long i19034 = 0; i19034 < 2000; ++i19034) {
        long t19036 = i19034;
        long c190350 = t19036 / 1; t19036 %= 1;
        long c190351 = t19036;
        r675[i19034] = r674[c190350 * 1];
    }
    /* gather [gather] -> r676 */
    for (long i19037 = 0; i19037 < 2000; ++i19037) {
        long t19039 = i19037;
        long c190380 = t19039 / 2000; t19039 %= 2000;
        long c190381 = t19039;
        long row19040 = c190381 * 1;
        long s19041 = clamp_start((long)r675[row19040 + 0], 4000, 1);
        r676[i19037] = r671[c190380 * 4000 + s19041 * 1];
    }
    /* shl [shift_left] -> r677 */
    for (long i19042 = 0; i19042 < 2000; ++i19042) {
        r677[i19042] = shl32(r676[i19042], 1);
    }
    /* mov [device_put] -> r678 */
    memcpy(r678, r1, sizeof(int32_t) * 80);
    /* rev [rev] -> r679 */
    for (long i19043 = 0; i19043 < 80; ++i19043) {
        long t19045 = i19043;
        long c190440 = t19045 / 16; t19045 %= 16;
        long c190441 = t19045;
        r679[i19043] = r678[c190440 * 16 + (16 - 1 - c190441) * 1];
    }
    /* reshape [reshape] -> r680 */
    memcpy(r680, r679, sizeof(int32_t) * 80);
    /* convert [convert_element_type] -> r681 */
    for (long i19046 = 0; i19046 < 1; ++i19046) {
        r681[i19046] = (int32_t)r14[0];
    }
    /* pad [pad] -> r682 */
    for (long i19047 = 0; i19047 < 2015; ++i19047) {
        r682[i19047] = r681[0];
    }
    for (long i19048 = 0; i19048 < 2000; ++i19048) {
        long t19050 = i19048;
        long c190490 = t19050 / 2000; t19050 %= 2000;
        long c190491 = t19050;
        long d19051 = 0 + c190490 * 1;
        long d19052 = 15 + c190491 * 1;
        if (d19051 >= 0 && d19051 < 1 && d19052 >= 0 && d19052 < 2015) r682[d19051 * 2015 + d19052 * 1] = r677[i19048];
    }
    /* convert [convert_element_type] -> r683 */
    for (long i19053 = 0; i19053 < 1; ++i19053) {
        r683[i19053] = (int32_t)r14[0];
    }
    /* pad [pad] -> r684 */
    for (long i19054 = 0; i19054 < 2063; ++i19054) {
        r684[i19054] = r683[0];
    }
    for (long i19055 = 0; i19055 < 2015; ++i19055) {
        long t19057 = i19055;
        long c190560 = t19057 / 2015; t19057 %= 2015;
        long c190561 = t19057;
        long d19058 = 0 + c190560 * 1;
        long d19059 = 0 + c190561 * 1;
        if (d19058 >= 0 && d19058 < 1 && d19059 >= 0 && d19059 < 2063) r684[d19058 * 2063 + d19059 * 1] = r682[i19055];
    }
    /* iota [iota] -> r685 */
    for (long i19060 = 0; i19060 < 1024; ++i19060) {
        long t19062 = i19060;
        long c190610 = t19062;
        r685[i19060] = (int32_t)c190610;
    }
    /* broadcast [broadcast_in_dim] -> r686 */
    for (long i19063 = 0; i19063 < 1024; ++i19063) {
        long t19065 = i19063;
        long c190640 = t19065 / 1; t19065 %= 1;
        long c190641 = t19065;
        r686[i19063] = r685[c190640 * 1];
    }
    /* iota [iota] -> r687 */
    for (long i19066 = 0; i19066 < 16; ++i19066) {
        long t19068 = i19066;
        long c190670 = t19068;
        r687[i19066] = (int32_t)c190670;
    }
    /* broadcast [broadcast_in_dim] -> r688 */
    for (long i19069 = 0; i19069 < 16; ++i19069) {
        long t19071 = i19069;
        long c190700 = t19071 / 16; t19071 %= 16;
        long c190701 = t19071;
        r688[i19069] = r687[c190701 * 1];
    }
    /* add [add] -> r689 */
    for (long i19072 = 0; i19072 < 16384; ++i19072) {
        long t19074 = i19072;
        long c190730 = t19074 / 16; t19074 %= 16;
        long c190731 = t19074;
        r689[i19072] = add32(r686[c190730 * 1], r688[c190731 * 1]);
    }
    /* iota [iota] -> r690 */
    for (long i19075 = 0; i19075 < 2; ++i19075) {
        long t19077 = i19075;
        long c190760 = t19077;
        r690[i19075] = (int32_t)c190760;
    }
    /* shl [mul] -> r691 */
    for (long i19078 = 0; i19078 < 2; ++i19078) {
        r691[i19078] = shl32(r690[i19078], 10);
    }
    /* loop [scan] -> r774 */
    memcpy(r692, r684, sizeof(int32_t) * 2063);
    memcpy(r693, r689, sizeof(int32_t) * 16384);
    memcpy(r694, r680, sizeof(int32_t) * 80);
    for (long t19079 = 0; t19079 < 2; ++t19079) {
        memcpy(r695, r691 + t19079 * 1, sizeof(int32_t) * 1);
        /* add [add] -> r696 */
        for (long i20080 = 0; i20080 < 1; ++i20080) {
            r696[i20080] = add32(r14[0], r9[0]);
        }
        /* select_n [select_n] -> r697 */
        for (long i20081 = 0; i20081 < 1; ++i20081) {
            r697[i20081] = r31[0] == 0 ? r14[0] : (r696[0]);
        }
        /* lt [lt] -> r698 */
        for (long i20082 = 0; i20082 < 1; ++i20082) {
            r698[i20082] = r695[0] < r14[0] ? 1 : 0;
        }
        /* add [add] -> r700 */
        for (long i20083 = 0; i20083 < 1; ++i20083) {
            r700[i20083] = add32(r695[0], r699[0]);
        }
        /* select_n [select_n] -> r701 */
        for (long i20084 = 0; i20084 < 1; ++i20084) {
            r701[i20084] = r698[0] == 0 ? r695[0] : (r700[0]);
        }
        /* dynamic_slice [dynamic_slice] -> r702 */
        long s20085 = clamp_start((long)r697[0], 1, 1);
        long s20086 = clamp_start((long)r701[0], 2063, 1039);
        {
        for (long i20087 = 0; i20087 < 1039; ++i20087) {
            long t20089 = i20087;
            long c200880 = t20089 / 1039; t20089 %= 1039;
            long c200881 = t20089;
            r702[i20087] = r692[(s20085 + c200880) * 2063 + (s20086 + c200881) * 1];
        }
        }
        /* lt [lt] -> r703 */
        for (long i20090 = 0; i20090 < 16384; ++i20090) {
            r703[i20090] = r693[i20090] < r14[0] ? 1 : 0;
        }
        /* add [add] -> r704 */
        for (long i20091 = 0; i20091 < 16384; ++i20091) {
            r704[i20091] = add32(r693[i20091], r39[0]);
        }
        /* select_n [select_n] -> r705 */
        for (long i20092 = 0; i20092 < 16384; ++i20092) {
            r705[i20092] = r703[i20092] == 0 ? r693[i20092] : (r704[i20092]);
        }
        /* broadcast [broadcast_in_dim] -> r706 */
        for (long i20093 = 0; i20093 < 16384; ++i20093) {
            long t20095 = i20093;
            long c200940 = t20095 / 16; t20095 %= 16;
            long c200941 = t20095 / 1; t20095 %= 1;
            long c200942 = t20095;
            r706[i20093] = r705[c200940 * 16 + c200941 * 1];
        }
        /* gather [gather] -> r707 */
        for (long i20096 = 0; i20096 < 16384; ++i20096) {
            long t20098 = i20096;
            long c200970 = t20098 / 16384; t20098 %= 16384;
            long c200971 = t20098 / 16; t20098 %= 16;
            long c200972 = t20098;
            long row20099 = c200971 * 16 + c200972 * 1;
            long s20100 = clamp_start((long)r706[row20099 + 0], 1039, 1);
            r707[i20096] = r702[c200970 * 1039 + s20100 * 1];
        }
        /* broadcast [broadcast_in_dim] -> r708 */
        for (long i20101 = 0; i20101 < 16384; ++i20101) {
            long t20103 = i20101;
            long c201020 = t20103 / 16384; t20103 %= 16384;
            long c201021 = t20103 / 16384; t20103 %= 16384;
            long c201022 = t20103 / 16; t20103 %= 16;
            long c201023 = t20103;
            r708[i20101] = r707[c201022 * 16 + c201023 * 1];
        }
        /* add [add] -> r709 */
        for (long i20104 = 0; i20104 < 81920; ++i20104) {
            long t20106 = i20104;
            long c201050 = t20106 / 16384; t20106 %= 16384;
            long c201051 = t20106 / 16384; t20106 %= 16384;
            long c201052 = t20106 / 16; t20106 %= 16;
            long c201053 = t20106;
            r709[i20104] = add32(r694[c201050 * 16 + c201053 * 1], r708[c201052 * 16 + c201053 * 1]);
        }
        /* convert [convert_element_type] -> r710 */
        for (long i20107 = 0; i20107 < 1; ++i20107) {
            r710[i20107] = (int32_t)r46[0];
        }
        /* max [max] -> r711 */
        for (long i20108 = 0; i20108 < 81920; ++i20108) {
            r711[i20108] = max32(r710[0], r709[i20108]);
        }
        /* convert [convert_element_type] -> r712 */
        for (long i20109 = 0; i20109 < 1; ++i20109) {
            r712[i20109] = (int32_t)r47[0];
        }
        /* min [min] -> r713 */
        for (long i20110 = 0; i20110 < 81920; ++i20110) {
            r713[i20110] = min32(r712[0], r711[i20110]);
        }
        /* sub [sub] -> r714 */
        for (long i20111 = 0; i20111 < 81920; ++i20111) {
            long t20113 = i20111;
            long c201120 = t20113 / 16384; t20113 %= 16384;
            long c201121 = t20113 / 16384; t20113 %= 16384;
            long c201122 = t20113 / 16; t20113 %= 16;
            long c201123 = t20113;
            r714[i20111] = sub32(r694[c201120 * 16 + c201123 * 1], r708[c201122 * 16 + c201123 * 1]);
        }
        /* convert [convert_element_type] -> r715 */
        for (long i20114 = 0; i20114 < 1; ++i20114) {
            r715[i20114] = (int32_t)r46[0];
        }
        /* max [max] -> r716 */
        for (long i20115 = 0; i20115 < 81920; ++i20115) {
            r716[i20115] = max32(r715[0], r714[i20115]);
        }
        /* convert [convert_element_type] -> r717 */
        for (long i20116 = 0; i20116 < 1; ++i20116) {
            r717[i20116] = (int32_t)r47[0];
        }
        /* min [min] -> r718 */
        for (long i20117 = 0; i20117 < 81920; ++i20117) {
            r718[i20117] = min32(r717[0], r716[i20117]);
        }
        /* abs [abs] -> r719 */
        for (long i20118 = 0; i20118 < 81920; ++i20118) {
            r719[i20118] = abs32(r713[i20118]);
        }
        /* reduce_max [reduce_max] -> r720 */
        for (long i20119 = 0; i20119 < 5120; ++i20119) {
            r720[i20119] = (-2147483647 - 1);
        }
        for (long i20120 = 0; i20120 < 81920; ++i20120) {
            long t20122 = i20120;
            long c201210 = t20122 / 16384; t20122 %= 16384;
            long c201211 = t20122 / 16384; t20122 %= 16384;
            long c201212 = t20122 / 16; t20122 %= 16;
            long c201213 = t20122;
            r720[c201210 * 1024 + c201211 * 1024 + c201212 * 1] = max32(r720[c201210 * 1024 + c201211 * 1024 + c201212 * 1], r719[i20120]);
        }
        /* sub [sub] -> r721 */
        for (long i20123 = 0; i20123 < 5120; ++i20123) {
            r721[i20123] = sub32(r720[i20123], r59[0]);
        }
        /* loop [scan] -> r743 */
        memcpy(r722, r713, sizeof(int32_t) * 81920);
        memcpy(r723, r59, sizeof(int32_t) * 1);
        memcpy(r724, r14, sizeof(int32_t) * 1);
        memcpy(r725, r721, sizeof(int32_t) * 5120);
        memcpy(r726, r720, sizeof(int32_t) * 5120);
        for (long t20124 = 0; t20124 < 12; ++t20124) {
            /* add [add] -> r727 */
            for (long i21125 = 0; i21125 < 1; ++i21125) {
                r727[i21125] = add32(r724[0], r9[0]);
            }
            /* add [add] -> r728 */
            for (long i21126 = 0; i21126 < 5120; ++i21126) {
                r728[i21126] = add32(r725[i21126], r726[i21126]);
            }
            /* shra [shift_right_arithmetic] -> r729 */
            for (long i21127 = 0; i21127 < 5120; ++i21127) {
                r729[i21127] = asr32(r728[i21127], 1);
            }
            /* broadcast [broadcast_in_dim] -> r730 */
            for (long i21128 = 0; i21128 < 5120; ++i21128) {
                long t21130 = i21128;
                long c211290 = t21130 / 1024; t21130 %= 1024;
                long c211291 = t21130 / 1024; t21130 %= 1024;
                long c211292 = t21130 / 1; t21130 %= 1;
                long c211293 = t21130;
                r730[i21128] = r729[c211290 * 1024 + c211292 * 1];
            }
            /* sub [sub] -> r731 */
            for (long i21131 = 0; i21131 < 81920; ++i21131) {
                long t21133 = i21131;
                long c211320 = t21133 / 16384; t21133 %= 16384;
                long c211321 = t21133 / 16384; t21133 %= 16384;
                long c211322 = t21133 / 16; t21133 %= 16;
                long c211323 = t21133;
                r731[i21131] = sub32(r722[c211320 * 16384 + c211322 * 16 + c211323 * 1], r730[c211320 * 1024 + c211322 * 1]);
            }
            /* max [max] -> r732 */
            for (long i21134 = 0; i21134 < 81920; ++i21134) {
                r732[i21134] = max32(r731[i21134], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r733 */
            for (long i21135 = 0; i21135 < 5120; ++i21135) {
                r733[i21135] = 0;
            }
            for (long i21136 = 0; i21136 < 81920; ++i21136) {
                long t21138 = i21136;
                long c211370 = t21138 / 16384; t21138 %= 16384;
                long c211371 = t21138 / 16384; t21138 %= 16384;
                long c211372 = t21138 / 16; t21138 %= 16;
                long c211373 = t21138;
                r733[c211370 * 1024 + c211371 * 1024 + c211372 * 1] = add32(r733[c211370 * 1024 + c211371 * 1024 + c211372 * 1], r732[i21136]);
            }
            /* neg [neg] -> r734 */
            for (long i21139 = 0; i21139 < 81920; ++i21139) {
                r734[i21139] = neg32(r722[i21139]);
            }
            /* broadcast [broadcast_in_dim] -> r735 */
            for (long i21140 = 0; i21140 < 5120; ++i21140) {
                long t21142 = i21140;
                long c211410 = t21142 / 1024; t21142 %= 1024;
                long c211411 = t21142 / 1024; t21142 %= 1024;
                long c211412 = t21142 / 1; t21142 %= 1;
                long c211413 = t21142;
                r735[i21140] = r729[c211410 * 1024 + c211412 * 1];
            }
            /* sub [sub] -> r736 */
            for (long i21143 = 0; i21143 < 81920; ++i21143) {
                long t21145 = i21143;
                long c211440 = t21145 / 16384; t21145 %= 16384;
                long c211441 = t21145 / 16384; t21145 %= 16384;
                long c211442 = t21145 / 16; t21145 %= 16;
                long c211443 = t21145;
                r736[i21143] = sub32(r734[c211440 * 16384 + c211442 * 16 + c211443 * 1], r735[c211440 * 1024 + c211442 * 1]);
            }
            /* max [max] -> r737 */
            for (long i21146 = 0; i21146 < 81920; ++i21146) {
                r737[i21146] = max32(r736[i21146], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r738 */
            for (long i21147 = 0; i21147 < 5120; ++i21147) {
                r738[i21147] = 0;
            }
            for (long i21148 = 0; i21148 < 81920; ++i21148) {
                long t21150 = i21148;
                long c211490 = t21150 / 16384; t21150 %= 16384;
                long c211491 = t21150 / 16384; t21150 %= 16384;
                long c211492 = t21150 / 16; t21150 %= 16;
                long c211493 = t21150;
                r738[c211490 * 1024 + c211491 * 1024 + c211492 * 1] = add32(r738[c211490 * 1024 + c211491 * 1024 + c211492 * 1], r737[i21148]);
            }
            /* add [add] -> r739 */
            for (long i21151 = 0; i21151 < 5120; ++i21151) {
                r739[i21151] = add32(r733[i21151], r738[i21151]);
            }
            /* gt [gt] -> r740 */
            for (long i21152 = 0; i21152 < 5120; ++i21152) {
                r740[i21152] = r739[i21152] > r723[0] ? 1 : 0;
            }
            /* select_n [select_n] -> r741 */
            for (long i21153 = 0; i21153 < 5120; ++i21153) {
                r741[i21153] = r740[i21153] == 0 ? r725[i21153] : (r729[i21153]);
            }
            /* select_n [select_n] -> r742 */
            for (long i21154 = 0; i21154 < 5120; ++i21154) {
                r742[i21154] = r740[i21154] == 0 ? r729[i21154] : (r726[i21154]);
            }
            memcpy(r724, r727, sizeof(int32_t) * 1);
            memcpy(r725, r741, sizeof(int32_t) * 5120);
            memcpy(r726, r742, sizeof(int32_t) * 5120);
        }
        memcpy(r743, r724, sizeof(int32_t) * 1);
        memcpy(r744, r725, sizeof(int32_t) * 5120);
        memcpy(r745, r726, sizeof(int32_t) * 5120);
        /* abs [abs] -> r746 */
        for (long i21155 = 0; i21155 < 81920; ++i21155) {
            r746[i21155] = abs32(r718[i21155]);
        }
        /* reduce_max [reduce_max] -> r747 */
        for (long i21156 = 0; i21156 < 5120; ++i21156) {
            r747[i21156] = (-2147483647 - 1);
        }
        for (long i21157 = 0; i21157 < 81920; ++i21157) {
            long t21159 = i21157;
            long c211580 = t21159 / 16384; t21159 %= 16384;
            long c211581 = t21159 / 16384; t21159 %= 16384;
            long c211582 = t21159 / 16; t21159 %= 16;
            long c211583 = t21159;
            r747[c211580 * 1024 + c211581 * 1024 + c211582 * 1] = max32(r747[c211580 * 1024 + c211581 * 1024 + c211582 * 1], r746[i21157]);
        }
        /* sub [sub] -> r748 */
        for (long i21160 = 0; i21160 < 5120; ++i21160) {
            r748[i21160] = sub32(r747[i21160], r59[0]);
        }
        /* loop [scan] -> r770 */
        memcpy(r749, r718, sizeof(int32_t) * 81920);
        memcpy(r750, r59, sizeof(int32_t) * 1);
        memcpy(r751, r14, sizeof(int32_t) * 1);
        memcpy(r752, r748, sizeof(int32_t) * 5120);
        memcpy(r753, r747, sizeof(int32_t) * 5120);
        for (long t21161 = 0; t21161 < 12; ++t21161) {
            /* add [add] -> r754 */
            for (long i22162 = 0; i22162 < 1; ++i22162) {
                r754[i22162] = add32(r751[0], r9[0]);
            }
            /* add [add] -> r755 */
            for (long i22163 = 0; i22163 < 5120; ++i22163) {
                r755[i22163] = add32(r752[i22163], r753[i22163]);
            }
            /* shra [shift_right_arithmetic] -> r756 */
            for (long i22164 = 0; i22164 < 5120; ++i22164) {
                r756[i22164] = asr32(r755[i22164], 1);
            }
            /* broadcast [broadcast_in_dim] -> r757 */
            for (long i22165 = 0; i22165 < 5120; ++i22165) {
                long t22167 = i22165;
                long c221660 = t22167 / 1024; t22167 %= 1024;
                long c221661 = t22167 / 1024; t22167 %= 1024;
                long c221662 = t22167 / 1; t22167 %= 1;
                long c221663 = t22167;
                r757[i22165] = r756[c221660 * 1024 + c221662 * 1];
            }
            /* sub [sub] -> r758 */
            for (long i22168 = 0; i22168 < 81920; ++i22168) {
                long t22170 = i22168;
                long c221690 = t22170 / 16384; t22170 %= 16384;
                long c221691 = t22170 / 16384; t22170 %= 16384;
                long c221692 = t22170 / 16; t22170 %= 16;
                long c221693 = t22170;
                r758[i22168] = sub32(r749[c221690 * 16384 + c221692 * 16 + c221693 * 1], r757[c221690 * 1024 + c221692 * 1]);
            }
            /* max [max] -> r759 */
            for (long i22171 = 0; i22171 < 81920; ++i22171) {
                r759[i22171] = max32(r758[i22171], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r760 */
            for (long i22172 = 0; i22172 < 5120; ++i22172) {
                r760[i22172] = 0;
            }
            for (long i22173 = 0; i22173 < 81920; ++i22173) {
                long t22175 = i22173;
                long c221740 = t22175 / 16384; t22175 %= 16384;
                long c221741 = t22175 / 16384; t22175 %= 16384;
                long c221742 = t22175 / 16; t22175 %= 16;
                long c221743 = t22175;
                r760[c221740 * 1024 + c221741 * 1024 + c221742 * 1] = add32(r760[c221740 * 1024 + c221741 * 1024 + c221742 * 1], r759[i22173]);
            }
            /* neg [neg] -> r761 */
            for (long i22176 = 0; i22176 < 81920; ++i22176) {
                r761[i22176] = neg32(r749[i22176]);
            }
            /* broadcast [broadcast_in_dim] -> r762 */
            for (long i22177 = 0; i22177 < 5120; ++i22177) {
                long t22179 = i22177;
                long c221780 = t22179 / 1024; t22179 %= 1024;
                long c221781 = t22179 / 1024; t22179 %= 1024;
                long c221782 = t22179 / 1; t22179 %= 1;
                long c221783 = t22179;
                r762[i22177] = r756[c221780 * 1024 + c221782 * 1];
            }
            /* sub [sub] -> r763 */
            for (long i22180 = 0; i22180 < 81920; ++i22180) {
                long t22182 = i22180;
                long c221810 = t22182 / 16384; t22182 %= 16384;
                long c221811 = t22182 / 16384; t22182 %= 16384;
                long c221812 = t22182 / 16; t22182 %= 16;
                long c221813 = t22182;
                r763[i22180] = sub32(r761[c221810 * 16384 + c221812 * 16 + c221813 * 1], r762[c221810 * 1024 + c221812 * 1]);
            }
            /* max [max] -> r764 */
            for (long i22183 = 0; i22183 < 81920; ++i22183) {
                r764[i22183] = max32(r763[i22183], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r765 */
            for (long i22184 = 0; i22184 < 5120; ++i22184) {
                r765[i22184] = 0;
            }
            for (long i22185 = 0; i22185 < 81920; ++i22185) {
                long t22187 = i22185;
                long c221860 = t22187 / 16384; t22187 %= 16384;
                long c221861 = t22187 / 16384; t22187 %= 16384;
                long c221862 = t22187 / 16; t22187 %= 16;
                long c221863 = t22187;
                r765[c221860 * 1024 + c221861 * 1024 + c221862 * 1] = add32(r765[c221860 * 1024 + c221861 * 1024 + c221862 * 1], r764[i22185]);
            }
            /* add [add] -> r766 */
            for (long i22188 = 0; i22188 < 5120; ++i22188) {
                r766[i22188] = add32(r760[i22188], r765[i22188]);
            }
            /* gt [gt] -> r767 */
            for (long i22189 = 0; i22189 < 5120; ++i22189) {
                r767[i22189] = r766[i22189] > r750[0] ? 1 : 0;
            }
            /* select_n [select_n] -> r768 */
            for (long i22190 = 0; i22190 < 5120; ++i22190) {
                r768[i22190] = r767[i22190] == 0 ? r752[i22190] : (r756[i22190]);
            }
            /* select_n [select_n] -> r769 */
            for (long i22191 = 0; i22191 < 5120; ++i22191) {
                r769[i22191] = r767[i22191] == 0 ? r756[i22191] : (r753[i22191]);
            }
            memcpy(r751, r754, sizeof(int32_t) * 1);
            memcpy(r752, r768, sizeof(int32_t) * 5120);
            memcpy(r753, r769, sizeof(int32_t) * 5120);
        }
        memcpy(r770, r751, sizeof(int32_t) * 1);
        memcpy(r771, r752, sizeof(int32_t) * 5120);
        memcpy(r772, r753, sizeof(int32_t) * 5120);
        /* sub [sub] -> r773 */
        for (long i22192 = 0; i22192 < 5120; ++i22192) {
            r773[i22192] = sub32(r745[i22192], r772[i22192]);
        }
        memcpy(r774 + t19079 * 5120, r773, sizeof(int32_t) * 5120);
    }
    /* transpose [transpose] -> r775 */
    for (long i22193 = 0; i22193 < 10240; ++i22193) {
        long t22195 = i22193;
        long c221940 = t22195 / 2048; t22195 %= 2048;
        long c221941 = t22195 / 2048; t22195 %= 2048;
        long c221942 = t22195 / 1024; t22195 %= 1024;
        long c221943 = t22195;
        r775[i22193] = r774[c221940 * 1024 + c221941 * 1024 + c221942 * 5120 + c221943 * 1];
    }
    /* reshape [reshape] -> r776 */
    memcpy(r776, r775, sizeof(int32_t) * 10240);
    /* slice [slice] -> r777 */
    for (long i22196 = 0; i22196 < 10000; ++i22196) {
        long t22198 = i22196;
        long c221970 = t22198 / 2000; t22198 %= 2000;
        long c221971 = t22198 / 2000; t22198 %= 2000;
        long c221972 = t22198;
        r777[i22196] = r776[(0 + c221970 * 1) * 2048 + (0 + c221971 * 1) * 2048 + (0 + c221972 * 1) * 1];
    }
    /* transpose [transpose] -> r778 */
    for (long i22199 = 0; i22199 < 10000; ++i22199) {
        long t22201 = i22199;
        long c222000 = t22201 / 10000; t22201 %= 10000;
        long c222001 = t22201 / 2000; t22201 %= 2000;
        long c222002 = t22201;
        r778[i22199] = r777[c222000 * 2000 + c222001 * 2000 + c222002 * 1];
    }
    /* max [max] -> r779 */
    for (long i22202 = 0; i22202 < 10000; ++i22202) {
        r779[i22202] = max32(r778[i22202], r14[0]);
    }
    /* reduce_sum [reduce_sum] -> r780 */
    for (long i22203 = 0; i22203 < 5; ++i22203) {
        r780[i22203] = 0;
    }
    for (long i22204 = 0; i22204 < 10000; ++i22204) {
        long t22206 = i22204;
        long c222050 = t22206 / 10000; t22206 %= 10000;
        long c222051 = t22206 / 2000; t22206 %= 2000;
        long c222052 = t22206;
        r780[c222050 * 5 + c222051 * 1] = add32(r780[c222050 * 5 + c222051 * 1], r779[i22204]);
    }
    /* shl [shift_left] -> r782 */
    for (long i22207 = 0; i22207 < 5; ++i22207) {
        r782[i22207] = shl32(r780[i22207], 3);
    }
    /* shl [shift_left] -> r783 */
    for (long i22208 = 0; i22208 < 2000; ++i22208) {
        r783[i22208] = shl32(r676[i22208], 1);
    }
    /* mov [device_put] -> r784 */
    memcpy(r784, r2, sizeof(int32_t) * 6);
    /* rev [rev] -> r785 */
    for (long i22209 = 0; i22209 < 6; ++i22209) {
        long t22211 = i22209;
        long c222100 = t22211 / 6; t22211 %= 6;
        long c222101 = t22211;
        r785[i22209] = r784[c222100 * 6 + (6 - 1 - c222101) * 1];
    }
    /* reshape [reshape] -> r786 */
    memcpy(r786, r785, sizeof(int32_t) * 6);
    /* convert [convert_element_type] -> r787 */
    for (long i22212 = 0; i22212 < 1; ++i22212) {
        r787[i22212] = (int32_t)r14[0];
    }
    /* pad [pad] -> r788 */
    for (long i22213 = 0; i22213 < 2005; ++i22213) {
        r788[i22213] = r787[0];
    }
    for (long i22214 = 0; i22214 < 2000; ++i22214) {
        long t22216 = i22214;
        long c222150 = t22216 / 2000; t22216 %= 2000;
        long c222151 = t22216;
        long d22217 = 0 + c222150 * 1;
        long d22218 = 5 + c222151 * 1;
        if (d22217 >= 0 && d22217 < 1 && d22218 >= 0 && d22218 < 2005) r788[d22217 * 2005 + d22218 * 1] = r783[i22214];
    }
    /* convert [convert_element_type] -> r789 */
    for (long i22219 = 0; i22219 < 1; ++i22219) {
        r789[i22219] = (int32_t)r14[0];
    }
    /* pad [pad] -> r790 */
    for (long i22220 = 0; i22220 < 2053; ++i22220) {
        r790[i22220] = r789[0];
    }
    for (long i22221 = 0; i22221 < 2005; ++i22221) {
        long t22223 = i22221;
        long c222220 = t22223 / 2005; t22223 %= 2005;
        long c222221 = t22223;
        long d22224 = 0 + c222220 * 1;
        long d22225 = 0 + c222221 * 1;
        if (d22224 >= 0 && d22224 < 1 && d22225 >= 0 && d22225 < 2053) r790[d22224 * 2053 + d22225 * 1] = r788[i22221];
    }
    /* iota [iota] -> r791 */
    for (long i22226 = 0; i22226 < 1024; ++i22226) {
        long t22228 = i22226;
        long c222270 = t22228;
        r791[i22226] = (int32_t)c222270;
    }
    /* broadcast [broadcast_in_dim] -> r792 */
    for (long i22229 = 0; i22229 < 1024; ++i22229) {
        long t22231 = i22229;
        long c222300 = t22231 / 1; t22231 %= 1;
        long c222301 = t22231;
        r792[i22229] = r791[c222300 * 1];
    }
    /* iota [iota] -> r793 */
    for (long i22232 = 0; i22232 < 6; ++i22232) {
        long t22234 = i22232;
        long c222330 = t22234;
        r793[i22232] = (int32_t)c222330;
    }
    /* broadcast [broadcast_in_dim] -> r794 */
    for (long i22235 = 0; i22235 < 6; ++i22235) {
        long t22237 = i22235;
        long c222360 = t22237 / 6; t22237 %= 6;
        long c222361 = t22237;
        r794[i22235] = r793[c222361 * 1];
    }
    /* add [add] -> r795 */
    for (long i22238 = 0; i22238 < 6144; ++i22238) {
        long t22240 = i22238;
        long c222390 = t22240 / 6; t22240 %= 6;
        long c222391 = t22240;
        r795[i22238] = add32(r792[c222390 * 1], r794[c222391 * 1]);
    }
    /* iota [iota] -> r796 */
    for (long i22241 = 0; i22241 < 2; ++i22241) {
        long t22243 = i22241;
        long c222420 = t22243;
        r796[i22241] = (int32_t)c222420;
    }
    /* shl [mul] -> r797 */
    for (long i22244 = 0; i22244 < 2; ++i22244) {
        r797[i22244] = shl32(r796[i22244], 10);
    }
    /* loop [scan] -> r880 */
    memcpy(r798, r790, sizeof(int32_t) * 2053);
    memcpy(r799, r795, sizeof(int32_t) * 6144);
    memcpy(r800, r786, sizeof(int32_t) * 6);
    for (long t22245 = 0; t22245 < 2; ++t22245) {
        memcpy(r801, r797 + t22245 * 1, sizeof(int32_t) * 1);
        /* add [add] -> r802 */
        for (long i23246 = 0; i23246 < 1; ++i23246) {
            r802[i23246] = add32(r14[0], r9[0]);
        }
        /* select_n [select_n] -> r803 */
        for (long i23247 = 0; i23247 < 1; ++i23247) {
            r803[i23247] = r31[0] == 0 ? r14[0] : (r802[0]);
        }
        /* lt [lt] -> r804 */
        for (long i23248 = 0; i23248 < 1; ++i23248) {
            r804[i23248] = r801[0] < r14[0] ? 1 : 0;
        }
        /* add [add] -> r806 */
        for (long i23249 = 0; i23249 < 1; ++i23249) {
            r806[i23249] = add32(r801[0], r805[0]);
        }
        /* select_n [select_n] -> r807 */
        for (long i23250 = 0; i23250 < 1; ++i23250) {
            r807[i23250] = r804[0] == 0 ? r801[0] : (r806[0]);
        }
        /* dynamic_slice [dynamic_slice] -> r808 */
        long s23251 = clamp_start((long)r803[0], 1, 1);
        long s23252 = clamp_start((long)r807[0], 2053, 1029);
        {
        for (long i23253 = 0; i23253 < 1029; ++i23253) {
            long t23255 = i23253;
            long c232540 = t23255 / 1029; t23255 %= 1029;
            long c232541 = t23255;
            r808[i23253] = r798[(s23251 + c232540) * 2053 + (s23252 + c232541) * 1];
        }
        }
        /* lt [lt] -> r809 */
        for (long i23256 = 0; i23256 < 6144; ++i23256) {
            r809[i23256] = r799[i23256] < r14[0] ? 1 : 0;
        }
        /* add [add] -> r810 */
        for (long i23257 = 0; i23257 < 6144; ++i23257) {
            r810[i23257] = add32(r799[i23257], r148[0]);
        }
        /* select_n [select_n] -> r811 */
        for (long i23258 = 0; i23258 < 6144; ++i23258) {
            r811[i23258] = r809[i23258] == 0 ? r799[i23258] : (r810[i23258]);
        }
        /* broadcast [broadcast_in_dim] -> r812 */
        for (long i23259 = 0; i23259 < 6144; ++i23259) {
            long t23261 = i23259;
            long c232600 = t23261 / 6; t23261 %= 6;
            long c232601 = t23261 / 1; t23261 %= 1;
            long c232602 = t23261;
            r812[i23259] = r811[c232600 * 6 + c232601 * 1];
        }
        /* gather [gather] -> r813 */
        for (long i23262 = 0; i23262 < 6144; ++i23262) {
            long t23264 = i23262;
            long c232630 = t23264 / 6144; t23264 %= 6144;
            long c232631 = t23264 / 6; t23264 %= 6;
            long c232632 = t23264;
            long row23265 = c232631 * 6 + c232632 * 1;
            long s23266 = clamp_start((long)r812[row23265 + 0], 1029, 1);
            r813[i23262] = r808[c232630 * 1029 + s23266 * 1];
        }
        /* broadcast [broadcast_in_dim] -> r814 */
        for (long i23267 = 0; i23267 < 6144; ++i23267) {
            long t23269 = i23267;
            long c232680 = t23269 / 6144; t23269 %= 6144;
            long c232681 = t23269 / 6144; t23269 %= 6144;
            long c232682 = t23269 / 6; t23269 %= 6;
            long c232683 = t23269;
            r814[i23267] = r813[c232682 * 6 + c232683 * 1];
        }
        /* add [add] -> r815 */
        for (long i23270 = 0; i23270 < 6144; ++i23270) {
            long t23272 = i23270;
            long c232710 = t23272 / 6144; t23272 %= 6144;
            long c232711 = t23272 / 6144; t23272 %= 6144;
            long c232712 = t23272 / 6; t23272 %= 6;
            long c232713 = t23272;
            r815[i23270] = add32(r800[c232713 * 1], r814[c232712 * 6 + c232713 * 1]);
        }
        /* convert [convert_element_type] -> r816 */
        for (long i23273 = 0; i23273 < 1; ++i23273) {
            r816[i23273] = (int32_t)r46[0];
        }
        /* max [max] -> r817 */
        for (long i23274 = 0; i23274 < 6144; ++i23274) {
            r817[i23274] = max32(r816[0], r815[i23274]);
        }
        /* convert [convert_element_type] -> r818 */
        for (long i23275 = 0; i23275 < 1; ++i23275) {
            r818[i23275] = (int32_t)r47[0];
        }
        /* min [min] -> r819 */
        for (long i23276 = 0; i23276 < 6144; ++i23276) {
            r819[i23276] = min32(r818[0], r817[i23276]);
        }
        /* sub [sub] -> r820 */
        for (long i23277 = 0; i23277 < 6144; ++i23277) {
            long t23279 = i23277;
            long c232780 = t23279 / 6144; t23279 %= 6144;
            long c232781 = t23279 / 6144; t23279 %= 6144;
            long c232782 = t23279 / 6; t23279 %= 6;
            long c232783 = t23279;
            r820[i23277] = sub32(r800[c232783 * 1], r814[c232782 * 6 + c232783 * 1]);
        }
        /* convert [convert_element_type] -> r821 */
        for (long i23280 = 0; i23280 < 1; ++i23280) {
            r821[i23280] = (int32_t)r46[0];
        }
        /* max [max] -> r822 */
        for (long i23281 = 0; i23281 < 6144; ++i23281) {
            r822[i23281] = max32(r821[0], r820[i23281]);
        }
        /* convert [convert_element_type] -> r823 */
        for (long i23282 = 0; i23282 < 1; ++i23282) {
            r823[i23282] = (int32_t)r47[0];
        }
        /* min [min] -> r824 */
        for (long i23283 = 0; i23283 < 6144; ++i23283) {
            r824[i23283] = min32(r823[0], r822[i23283]);
        }
        /* abs [abs] -> r825 */
        for (long i23284 = 0; i23284 < 6144; ++i23284) {
            r825[i23284] = abs32(r819[i23284]);
        }
        /* reduce_max [reduce_max] -> r826 */
        for (long i23285 = 0; i23285 < 1024; ++i23285) {
            r826[i23285] = (-2147483647 - 1);
        }
        for (long i23286 = 0; i23286 < 6144; ++i23286) {
            long t23288 = i23286;
            long c232870 = t23288 / 6144; t23288 %= 6144;
            long c232871 = t23288 / 6144; t23288 %= 6144;
            long c232872 = t23288 / 6; t23288 %= 6;
            long c232873 = t23288;
            r826[c232870 * 1024 + c232871 * 1024 + c232872 * 1] = max32(r826[c232870 * 1024 + c232871 * 1024 + c232872 * 1], r825[i23286]);
        }
        /* sub [sub] -> r827 */
        for (long i23289 = 0; i23289 < 1024; ++i23289) {
            r827[i23289] = sub32(r826[i23289], r59[0]);
        }
        /* loop [scan] -> r849 */
        memcpy(r828, r819, sizeof(int32_t) * 6144);
        memcpy(r829, r59, sizeof(int32_t) * 1);
        memcpy(r830, r14, sizeof(int32_t) * 1);
        memcpy(r831, r827, sizeof(int32_t) * 1024);
        memcpy(r832, r826, sizeof(int32_t) * 1024);
        for (long t23290 = 0; t23290 < 12; ++t23290) {
            /* add [add] -> r833 */
            for (long i24291 = 0; i24291 < 1; ++i24291) {
                r833[i24291] = add32(r830[0], r9[0]);
            }
            /* add [add] -> r834 */
            for (long i24292 = 0; i24292 < 1024; ++i24292) {
                r834[i24292] = add32(r831[i24292], r832[i24292]);
            }
            /* shra [shift_right_arithmetic] -> r835 */
            for (long i24293 = 0; i24293 < 1024; ++i24293) {
                r835[i24293] = asr32(r834[i24293], 1);
            }
            /* broadcast [broadcast_in_dim] -> r836 */
            for (long i24294 = 0; i24294 < 1024; ++i24294) {
                long t24296 = i24294;
                long c242950 = t24296 / 1024; t24296 %= 1024;
                long c242951 = t24296 / 1024; t24296 %= 1024;
                long c242952 = t24296 / 1; t24296 %= 1;
                long c242953 = t24296;
                r836[i24294] = r835[c242952 * 1];
            }
            /* sub [sub] -> r837 */
            for (long i24297 = 0; i24297 < 6144; ++i24297) {
                long t24299 = i24297;
                long c242980 = t24299 / 6144; t24299 %= 6144;
                long c242981 = t24299 / 6144; t24299 %= 6144;
                long c242982 = t24299 / 6; t24299 %= 6;
                long c242983 = t24299;
                r837[i24297] = sub32(r828[c242982 * 6 + c242983 * 1], r836[c242982 * 1]);
            }
            /* max [max] -> r838 */
            for (long i24300 = 0; i24300 < 6144; ++i24300) {
                r838[i24300] = max32(r837[i24300], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r839 */
            for (long i24301 = 0; i24301 < 1024; ++i24301) {
                r839[i24301] = 0;
            }
            for (long i24302 = 0; i24302 < 6144; ++i24302) {
                long t24304 = i24302;
                long c243030 = t24304 / 6144; t24304 %= 6144;
                long c243031 = t24304 / 6144; t24304 %= 6144;
                long c243032 = t24304 / 6; t24304 %= 6;
                long c243033 = t24304;
                r839[c243030 * 1024 + c243031 * 1024 + c243032 * 1] = add32(r839[c243030 * 1024 + c243031 * 1024 + c243032 * 1], r838[i24302]);
            }
            /* neg [neg] -> r840 */
            for (long i24305 = 0; i24305 < 6144; ++i24305) {
                r840[i24305] = neg32(r828[i24305]);
            }
            /* broadcast [broadcast_in_dim] -> r841 */
            for (long i24306 = 0; i24306 < 1024; ++i24306) {
                long t24308 = i24306;
                long c243070 = t24308 / 1024; t24308 %= 1024;
                long c243071 = t24308 / 1024; t24308 %= 1024;
                long c243072 = t24308 / 1; t24308 %= 1;
                long c243073 = t24308;
                r841[i24306] = r835[c243072 * 1];
            }
            /* sub [sub] -> r842 */
            for (long i24309 = 0; i24309 < 6144; ++i24309) {
                long t24311 = i24309;
                long c243100 = t24311 / 6144; t24311 %= 6144;
                long c243101 = t24311 / 6144; t24311 %= 6144;
                long c243102 = t24311 / 6; t24311 %= 6;
                long c243103 = t24311;
                r842[i24309] = sub32(r840[c243102 * 6 + c243103 * 1], r841[c243102 * 1]);
            }
            /* max [max] -> r843 */
            for (long i24312 = 0; i24312 < 6144; ++i24312) {
                r843[i24312] = max32(r842[i24312], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r844 */
            for (long i24313 = 0; i24313 < 1024; ++i24313) {
                r844[i24313] = 0;
            }
            for (long i24314 = 0; i24314 < 6144; ++i24314) {
                long t24316 = i24314;
                long c243150 = t24316 / 6144; t24316 %= 6144;
                long c243151 = t24316 / 6144; t24316 %= 6144;
                long c243152 = t24316 / 6; t24316 %= 6;
                long c243153 = t24316;
                r844[c243150 * 1024 + c243151 * 1024 + c243152 * 1] = add32(r844[c243150 * 1024 + c243151 * 1024 + c243152 * 1], r843[i24314]);
            }
            /* add [add] -> r845 */
            for (long i24317 = 0; i24317 < 1024; ++i24317) {
                r845[i24317] = add32(r839[i24317], r844[i24317]);
            }
            /* gt [gt] -> r846 */
            for (long i24318 = 0; i24318 < 1024; ++i24318) {
                r846[i24318] = r845[i24318] > r829[0] ? 1 : 0;
            }
            /* select_n [select_n] -> r847 */
            for (long i24319 = 0; i24319 < 1024; ++i24319) {
                r847[i24319] = r846[i24319] == 0 ? r831[i24319] : (r835[i24319]);
            }
            /* select_n [select_n] -> r848 */
            for (long i24320 = 0; i24320 < 1024; ++i24320) {
                r848[i24320] = r846[i24320] == 0 ? r835[i24320] : (r832[i24320]);
            }
            memcpy(r830, r833, sizeof(int32_t) * 1);
            memcpy(r831, r847, sizeof(int32_t) * 1024);
            memcpy(r832, r848, sizeof(int32_t) * 1024);
        }
        memcpy(r849, r830, sizeof(int32_t) * 1);
        memcpy(r850, r831, sizeof(int32_t) * 1024);
        memcpy(r851, r832, sizeof(int32_t) * 1024);
        /* abs [abs] -> r852 */
        for (long i24321 = 0; i24321 < 6144; ++i24321) {
            r852[i24321] = abs32(r824[i24321]);
        }
        /* reduce_max [reduce_max] -> r853 */
        for (long i24322 = 0; i24322 < 1024; ++i24322) {
            r853[i24322] = (-2147483647 - 1);
        }
        for (long i24323 = 0; i24323 < 6144; ++i24323) {
            long t24325 = i24323;
            long c243240 = t24325 / 6144; t24325 %= 6144;
            long c243241 = t24325 / 6144; t24325 %= 6144;
            long c243242 = t24325 / 6; t24325 %= 6;
            long c243243 = t24325;
            r853[c243240 * 1024 + c243241 * 1024 + c243242 * 1] = max32(r853[c243240 * 1024 + c243241 * 1024 + c243242 * 1], r852[i24323]);
        }
        /* sub [sub] -> r854 */
        for (long i24326 = 0; i24326 < 1024; ++i24326) {
            r854[i24326] = sub32(r853[i24326], r59[0]);
        }
        /* loop [scan] -> r876 */
        memcpy(r855, r824, sizeof(int32_t) * 6144);
        memcpy(r856, r59, sizeof(int32_t) * 1);
        memcpy(r857, r14, sizeof(int32_t) * 1);
        memcpy(r858, r854, sizeof(int32_t) * 1024);
        memcpy(r859, r853, sizeof(int32_t) * 1024);
        for (long t24327 = 0; t24327 < 12; ++t24327) {
            /* add [add] -> r860 */
            for (long i25328 = 0; i25328 < 1; ++i25328) {
                r860[i25328] = add32(r857[0], r9[0]);
            }
            /* add [add] -> r861 */
            for (long i25329 = 0; i25329 < 1024; ++i25329) {
                r861[i25329] = add32(r858[i25329], r859[i25329]);
            }
            /* shra [shift_right_arithmetic] -> r862 */
            for (long i25330 = 0; i25330 < 1024; ++i25330) {
                r862[i25330] = asr32(r861[i25330], 1);
            }
            /* broadcast [broadcast_in_dim] -> r863 */
            for (long i25331 = 0; i25331 < 1024; ++i25331) {
                long t25333 = i25331;
                long c253320 = t25333 / 1024; t25333 %= 1024;
                long c253321 = t25333 / 1024; t25333 %= 1024;
                long c253322 = t25333 / 1; t25333 %= 1;
                long c253323 = t25333;
                r863[i25331] = r862[c253322 * 1];
            }
            /* sub [sub] -> r864 */
            for (long i25334 = 0; i25334 < 6144; ++i25334) {
                long t25336 = i25334;
                long c253350 = t25336 / 6144; t25336 %= 6144;
                long c253351 = t25336 / 6144; t25336 %= 6144;
                long c253352 = t25336 / 6; t25336 %= 6;
                long c253353 = t25336;
                r864[i25334] = sub32(r855[c253352 * 6 + c253353 * 1], r863[c253352 * 1]);
            }
            /* max [max] -> r865 */
            for (long i25337 = 0; i25337 < 6144; ++i25337) {
                r865[i25337] = max32(r864[i25337], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r866 */
            for (long i25338 = 0; i25338 < 1024; ++i25338) {
                r866[i25338] = 0;
            }
            for (long i25339 = 0; i25339 < 6144; ++i25339) {
                long t25341 = i25339;
                long c253400 = t25341 / 6144; t25341 %= 6144;
                long c253401 = t25341 / 6144; t25341 %= 6144;
                long c253402 = t25341 / 6; t25341 %= 6;
                long c253403 = t25341;
                r866[c253400 * 1024 + c253401 * 1024 + c253402 * 1] = add32(r866[c253400 * 1024 + c253401 * 1024 + c253402 * 1], r865[i25339]);
            }
            /* neg [neg] -> r867 */
            for (long i25342 = 0; i25342 < 6144; ++i25342) {
                r867[i25342] = neg32(r855[i25342]);
            }
            /* broadcast [broadcast_in_dim] -> r868 */
            for (long i25343 = 0; i25343 < 1024; ++i25343) {
                long t25345 = i25343;
                long c253440 = t25345 / 1024; t25345 %= 1024;
                long c253441 = t25345 / 1024; t25345 %= 1024;
                long c253442 = t25345 / 1; t25345 %= 1;
                long c253443 = t25345;
                r868[i25343] = r862[c253442 * 1];
            }
            /* sub [sub] -> r869 */
            for (long i25346 = 0; i25346 < 6144; ++i25346) {
                long t25348 = i25346;
                long c253470 = t25348 / 6144; t25348 %= 6144;
                long c253471 = t25348 / 6144; t25348 %= 6144;
                long c253472 = t25348 / 6; t25348 %= 6;
                long c253473 = t25348;
                r869[i25346] = sub32(r867[c253472 * 6 + c253473 * 1], r868[c253472 * 1]);
            }
            /* max [max] -> r870 */
            for (long i25349 = 0; i25349 < 6144; ++i25349) {
                r870[i25349] = max32(r869[i25349], r14[0]);
            }
            /* reduce_sum [reduce_sum] -> r871 */
            for (long i25350 = 0; i25350 < 1024; ++i25350) {
                r871[i25350] = 0;
            }
            for (long i25351 = 0; i25351 < 6144; ++i25351) {
                long t25353 = i25351;
                long c253520 = t25353 / 6144; t25353 %= 6144;
                long c253521 = t25353 / 6144; t25353 %= 6144;
                long c253522 = t25353 / 6; t25353 %= 6;
                long c253523 = t25353;
                r871[c253520 * 1024 + c253521 * 1024 + c253522 * 1] = add32(r871[c253520 * 1024 + c253521 * 1024 + c253522 * 1], r870[i25351]);
            }
            /* add [add] -> r872 */
            for (long i25354 = 0; i25354 < 1024; ++i25354) {
                r872[i25354] = add32(r866[i25354], r871[i25354]);
            }
            /* gt [gt] -> r873 */
            for (long i25355 = 0; i25355 < 1024; ++i25355) {
                r873[i25355] = r872[i25355] > r856[0] ? 1 : 0;
            }
            /* select_n [select_n] -> r874 */
            for (long i25356 = 0; i25356 < 1024; ++i25356) {
                r874[i25356] = r873[i25356] == 0 ? r858[i25356] : (r862[i25356]);
            }
            /* select_n [select_n] -> r875 */
            for (long i25357 = 0; i25357 < 1024; ++i25357) {
                r875[i25357] = r873[i25357] == 0 ? r862[i25357] : (r859[i25357]);
            }
            memcpy(r857, r860, sizeof(int32_t) * 1);
            memcpy(r858, r874, sizeof(int32_t) * 1024);
            memcpy(r859, r875, sizeof(int32_t) * 1024);
        }
        memcpy(r876, r857, sizeof(int32_t) * 1);
        memcpy(r877, r858, sizeof(int32_t) * 1024);
        memcpy(r878, r859, sizeof(int32_t) * 1024);
        /* sub [sub] -> r879 */
        for (long i25358 = 0; i25358 < 1024; ++i25358) {
            r879[i25358] = sub32(r851[i25358], r878[i25358]);
        }
        memcpy(r880 + t22245 * 1024, r879, sizeof(int32_t) * 1024);
    }
    /* transpose [transpose] -> r881 */
    for (long i25359 = 0; i25359 < 2048; ++i25359) {
        long t25361 = i25359;
        long c253600 = t25361 / 2048; t25361 %= 2048;
        long c253601 = t25361 / 2048; t25361 %= 2048;
        long c253602 = t25361 / 1024; t25361 %= 1024;
        long c253603 = t25361;
        r881[i25359] = r880[c253600 * 1024 + c253601 * 1024 + c253602 * 1024 + c253603 * 1];
    }
    /* reshape [reshape] -> r882 */
    memcpy(r882, r881, sizeof(int32_t) * 2048);
    /* slice [slice] -> r883 */
    for (long i25362 = 0; i25362 < 2000; ++i25362) {
        long t25364 = i25362;
        long c253630 = t25364 / 2000; t25364 %= 2000;
        long c253631 = t25364 / 2000; t25364 %= 2000;
        long c253632 = t25364;
        r883[i25362] = r882[(0 + c253630 * 1) * 2048 + (0 + c253631 * 1) * 2048 + (0 + c253632 * 1) * 1];
    }
    /* transpose [transpose] -> r884 */
    for (long i25365 = 0; i25365 < 2000; ++i25365) {
        long t25367 = i25365;
        long c253660 = t25367 / 2000; t25367 %= 2000;
        long c253661 = t25367 / 2000; t25367 %= 2000;
        long c253662 = t25367;
        r884[i25365] = r883[c253660 * 2000 + c253661 * 2000 + c253662 * 1];
    }
    /* slice [slice] -> r885 */
    for (long i25368 = 0; i25368 < 2000; ++i25368) {
        long t25370 = i25368;
        long c253690 = t25370 / 2000; t25370 %= 2000;
        long c253691 = t25370 / 2000; t25370 %= 2000;
        long c253692 = t25370;
        r885[i25368] = r884[(0 + c253690 * 1) * 2000 + (0 + c253691 * 1) * 2000 + (0 + c253692 * 1) * 1];
    }
    /* reshape [squeeze] -> r886 */
    memcpy(r886, r885, sizeof(int32_t) * 2000);
    /* shra [shift_right_arithmetic] -> r887 */
    for (long i25371 = 0; i25371 < 2000; ++i25371) {
        r887[i25371] = asr32(r886[i25371], 1);
    }
    /* convert [convert_element_type] -> r888 */
    for (long i25372 = 0; i25372 < 1; ++i25372) {
        r888[i25372] = (int32_t)r227[0];
    }
    /* max [max] -> r889 */
    for (long i25373 = 0; i25373 < 2000; ++i25373) {
        r889[i25373] = max32(r888[0], r887[i25373]);
    }
    /* convert [convert_element_type] -> r890 */
    for (long i25374 = 0; i25374 < 1; ++i25374) {
        r890[i25374] = (int32_t)r228[0];
    }
    /* min [min] -> r891 */
    for (long i25375 = 0; i25375 < 2000; ++i25375) {
        r891[i25375] = min32(r890[0], r889[i25375]);
    }
    /* iota [iota] -> r892 */
    for (long i25376 = 0; i25376 < 1000; ++i25376) {
        long t25378 = i25376;
        long c253770 = t25378;
        r892[i25376] = (int32_t)c253770;
    }
    /* shl [mul] -> r893 */
    for (long i25379 = 0; i25379 < 1000; ++i25379) {
        r893[i25379] = shl32(r892[i25379], 1);
    }
    /* add [add] -> r894 */
    for (long i25380 = 0; i25380 < 1000; ++i25380) {
        r894[i25380] = add32(r14[0], r893[i25380]);
    }
    /* broadcast [broadcast_in_dim] -> r895 */
    for (long i25381 = 0; i25381 < 1000; ++i25381) {
        long t25383 = i25381;
        long c253820 = t25383 / 1; t25383 %= 1;
        long c253821 = t25383;
        r895[i25381] = r894[c253820 * 1];
    }
    /* gather [gather] -> r896 */
    for (long i25384 = 0; i25384 < 1000; ++i25384) {
        long t25386 = i25384;
        long c253850 = t25386 / 1000; t25386 %= 1000;
        long c253851 = t25386;
        long row25387 = c253851 * 1;
        long s25388 = clamp_start((long)r895[row25387 + 0], 2000, 1);
        r896[i25384] = r891[c253850 * 2000 + s25388 * 1];
    }
    /* shl [shift_left] -> r897 */
    for (long i25389 = 0; i25389 < 1000; ++i25389) {
        r897[i25389] = shl32(r896[i25389], 1);
    }
    /* mov [device_put] -> r898 */
    memcpy(r898, r1, sizeof(int32_t) * 80);
    /* rev [rev] -> r899 */
    for (long i25390 = 0; i25390 < 80; ++i25390) {
        long t25392 = i25390;
        long c253910 = t25392 / 16; t25392 %= 16;
        long c253911 = t25392;
        r899[i25390] = r898[c253910 * 16 + (16 - 1 - c253911) * 1];
    }
    /* reshape [reshape] -> r900 */
    memcpy(r900, r899, sizeof(int32_t) * 80);
    /* convert [convert_element_type] -> r901 */
    for (long i25393 = 0; i25393 < 1; ++i25393) {
        r901[i25393] = (int32_t)r14[0];
    }
    /* pad [pad] -> r902 */
    for (long i25394 = 0; i25394 < 1015; ++i25394) {
        r902[i25394] = r901[0];
    }
    for (long i25395 = 0; i25395 < 1000; ++i25395) {
        long t25397 = i25395;
        long c253960 = t25397 / 1000; t25397 %= 1000;
        long c253961 = t25397;
        long d25398 = 0 + c253960 * 1;
        long d25399 = 15 + c253961 * 1;
        if (d25398 >= 0 && d25398 < 1 && d25399 >= 0 && d25399 < 1015) r902[d25398 * 1015 + d25399 * 1] = r897[i25395];
    }
    /* iota [iota] -> r903 */
    for (long i25400 = 0; i25400 < 1000; ++i25400) {
        long t25402 = i25400;
        long c254010 = t25402;
        r903[i25400] = (int32_t)c254010;
    }
    /* broadcast [broadcast_in_dim] -> r904 */
    for (long i25403 = 0; i25403 < 1000; ++i25403) {
        long t25405 = i25403;
        long c254040 = t25405 / 1; t25405 %= 1;
        long c254041 = t25405;
        r904[i25403] = r903[c254040 * 1];
    }
    /* iota [iota] -> r905 */
    for (long i25406 = 0; i25406 < 16; ++i25406) {
        long t25408 = i25406;
        long c254070 = t25408;
        r905[i25406] = (int32_t)c254070;
    }
    /* broadcast [broadcast_in_dim] -> r906 */
    for (long i25409 = 0; i25409 < 16; ++i25409) {
        long t25411 = i25409;
        long c254100 = t25411 / 16; t25411 %= 16;
        long c254101 = t25411;
        r906[i25409] = r905[c254101 * 1];
    }
    /* add [add] -> r907 */
    for (long i25412 = 0; i25412 < 16000; ++i25412) {
        long t25414 = i25412;
        long c254130 = t25414 / 16; t25414 %= 16;
        long c254131 = t25414;
        r907[i25412] = add32(r904[c254130 * 1], r906[c254131 * 1]);
    }
    /* lt [lt] -> r908 */
    for (long i25415 = 0; i25415 < 16000; ++i25415) {
        r908[i25415] = r907[i25415] < r14[0] ? 1 : 0;
    }
    /* add [add] -> r910 */
    for (long i25416 = 0; i25416 < 16000; ++i25416) {
        r910[i25416] = add32(r907[i25416], r909[0]);
    }
    /* select_n [select_n] -> r911 */
    for (long i25417 = 0; i25417 < 16000; ++i25417) {
        r911[i25417] = r908[i25417] == 0 ? r907[i25417] : (r910[i25417]);
    }
    /* broadcast [broadcast_in_dim] -> r912 */
    for (long i25418 = 0; i25418 < 16000; ++i25418) {
        long t25420 = i25418;
        long c254190 = t25420 / 16; t25420 %= 16;
        long c254191 = t25420 / 1; t25420 %= 1;
        long c254192 = t25420;
        r912[i25418] = r911[c254190 * 16 + c254191 * 1];
    }
    /* gather [gather] -> r913 */
    for (long i25421 = 0; i25421 < 16000; ++i25421) {
        long t25423 = i25421;
        long c254220 = t25423 / 16000; t25423 %= 16000;
        long c254221 = t25423 / 16; t25423 %= 16;
        long c254222 = t25423;
        long row25424 = c254221 * 16 + c254222 * 1;
        long s25425 = clamp_start((long)r912[row25424 + 0], 1015, 1);
        r913[i25421] = r902[c254220 * 1015 + s25425 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r914 */
    for (long i25426 = 0; i25426 < 16000; ++i25426) {
        long t25428 = i25426;
        long c254270 = t25428 / 16000; t25428 %= 16000;
        long c254271 = t25428 / 16000; t25428 %= 16000;
        long c254272 = t25428 / 16; t25428 %= 16;
        long c254273 = t25428;
        r914[i25426] = r913[c254272 * 16 + c254273 * 1];
    }
    /* add [add] -> r915 */
    for (long i25429 = 0; i25429 < 80000; ++i25429) {
        long t25431 = i25429;
        long c254300 = t25431 / 16000; t25431 %= 16000;
        long c254301 = t25431 / 16000; t25431 %= 16000;
        long c254302 = t25431 / 16; t25431 %= 16;
        long c254303 = t25431;
        r915[i25429] = add32(r900[c254300 * 16 + c254303 * 1], r914[c254302 * 16 + c254303 * 1]);
    }
    /* convert [convert_element_type] -> r916 */
    for (long i25432 = 0; i25432 < 1; ++i25432) {
        r916[i25432] = (int32_t)r46[0];
    }
    /* max [max] -> r917 */
    for (long i25433 = 0; i25433 < 80000; ++i25433) {
        r917[i25433] = max32(r916[0], r915[i25433]);
    }
    /* convert [convert_element_type] -> r918 */
    for (long i25434 = 0; i25434 < 1; ++i25434) {
        r918[i25434] = (int32_t)r47[0];
    }
    /* min [min] -> r919 */
    for (long i25435 = 0; i25435 < 80000; ++i25435) {
        r919[i25435] = min32(r918[0], r917[i25435]);
    }
    /* sub [sub] -> r920 */
    for (long i25436 = 0; i25436 < 80000; ++i25436) {
        long t25438 = i25436;
        long c254370 = t25438 / 16000; t25438 %= 16000;
        long c254371 = t25438 / 16000; t25438 %= 16000;
        long c254372 = t25438 / 16; t25438 %= 16;
        long c254373 = t25438;
        r920[i25436] = sub32(r900[c254370 * 16 + c254373 * 1], r914[c254372 * 16 + c254373 * 1]);
    }
    /* convert [convert_element_type] -> r921 */
    for (long i25439 = 0; i25439 < 1; ++i25439) {
        r921[i25439] = (int32_t)r46[0];
    }
    /* max [max] -> r922 */
    for (long i25440 = 0; i25440 < 80000; ++i25440) {
        r922[i25440] = max32(r921[0], r920[i25440]);
    }
    /* convert [convert_element_type] -> r923 */
    for (long i25441 = 0; i25441 < 1; ++i25441) {
        r923[i25441] = (int32_t)r47[0];
    }
    /* min [min] -> r924 */
    for (long i25442 = 0; i25442 < 80000; ++i25442) {
        r924[i25442] = min32(r923[0], r922[i25442]);
    }
    /* abs [abs] -> r925 */
    for (long i25443 = 0; i25443 < 80000; ++i25443) {
        r925[i25443] = abs32(r919[i25443]);
    }
    /* reduce_max [reduce_max] -> r926 */
    for (long i25444 = 0; i25444 < 5000; ++i25444) {
        r926[i25444] = (-2147483647 - 1);
    }
    for (long i25445 = 0; i25445 < 80000; ++i25445) {
        long t25447 = i25445;
        long c254460 = t25447 / 16000; t25447 %= 16000;
        long c254461 = t25447 / 16000; t25447 %= 16000;
        long c254462 = t25447 / 16; t25447 %= 16;
        long c254463 = t25447;
        r926[c254460 * 1000 + c254461 * 1000 + c254462 * 1] = max32(r926[c254460 * 1000 + c254461 * 1000 + c254462 * 1], r925[i25445]);
    }
    /* sub [sub] -> r927 */
    for (long i25448 = 0; i25448 < 5000; ++i25448) {
        r927[i25448] = sub32(r926[i25448], r59[0]);
    }
    /* loop [scan] -> r949 */
    memcpy(r928, r919, sizeof(int32_t) * 80000);
    memcpy(r929, r59, sizeof(int32_t) * 1);
    memcpy(r930, r14, sizeof(int32_t) * 1);
    memcpy(r931, r927, sizeof(int32_t) * 5000);
    memcpy(r932, r926, sizeof(int32_t) * 5000);
    for (long t25449 = 0; t25449 < 12; ++t25449) {
        /* add [add] -> r933 */
        for (long i26450 = 0; i26450 < 1; ++i26450) {
            r933[i26450] = add32(r930[0], r9[0]);
        }
        /* add [add] -> r934 */
        for (long i26451 = 0; i26451 < 5000; ++i26451) {
            r934[i26451] = add32(r931[i26451], r932[i26451]);
        }
        /* shra [shift_right_arithmetic] -> r935 */
        for (long i26452 = 0; i26452 < 5000; ++i26452) {
            r935[i26452] = asr32(r934[i26452], 1);
        }
        /* broadcast [broadcast_in_dim] -> r936 */
        for (long i26453 = 0; i26453 < 5000; ++i26453) {
            long t26455 = i26453;
            long c264540 = t26455 / 1000; t26455 %= 1000;
            long c264541 = t26455 / 1000; t26455 %= 1000;
            long c264542 = t26455 / 1; t26455 %= 1;
            long c264543 = t26455;
            r936[i26453] = r935[c264540 * 1000 + c264542 * 1];
        }
        /* sub [sub] -> r937 */
        for (long i26456 = 0; i26456 < 80000; ++i26456) {
            long t26458 = i26456;
            long c264570 = t26458 / 16000; t26458 %= 16000;
            long c264571 = t26458 / 16000; t26458 %= 16000;
            long c264572 = t26458 / 16; t26458 %= 16;
            long c264573 = t26458;
            r937[i26456] = sub32(r928[c264570 * 16000 + c264572 * 16 + c264573 * 1], r936[c264570 * 1000 + c264572 * 1]);
        }
        /* max [max] -> r938 */
        for (long i26459 = 0; i26459 < 80000; ++i26459) {
            r938[i26459] = max32(r937[i26459], r14[0]);
        }
        /* reduce_sum [reduce_sum] -> r939 */
        for (long i26460 = 0; i26460 < 5000; ++i26460) {
            r939[i26460] = 0;
        }
        for (long i26461 = 0; i26461 < 80000; ++i26461) {
            long t26463 = i26461;
            long c264620 = t26463 / 16000; t26463 %= 16000;
            long c264621 = t26463 / 16000; t26463 %= 16000;
            long c264622 = t26463 / 16; t26463 %= 16;
            long c264623 = t26463;
            r939[c264620 * 1000 + c264621 * 1000 + c264622 * 1] = add32(r939[c264620 * 1000 + c264621 * 1000 + c264622 * 1], r938[i26461]);
        }
        /* neg [neg] -> r940 */
        for (long i26464 = 0; i26464 < 80000; ++i26464) {
            r940[i26464] = neg32(r928[i26464]);
        }
        /* broadcast [broadcast_in_dim] -> r941 */
        for (long i26465 = 0; i26465 < 5000; ++i26465) {
            long t26467 = i26465;
            long c264660 = t26467 / 1000; t26467 %= 1000;
            long c264661 = t26467 / 1000; t26467 %= 1000;
            long c264662 = t26467 / 1; t26467 %= 1;
            long c264663 = t26467;
            r941[i26465] = r935[c264660 * 1000 + c264662 * 1];
        }
        /* sub [sub] -> r942 */
        for (long i26468 = 0; i26468 < 80000; ++i26468) {
            long t26470 = i26468;
            long c264690 = t26470 / 16000; t26470 %= 16000;
            long c264691 = t26470 / 16000; t26470 %= 16000;
            long c264692 = t26470 / 16; t26470 %= 16;
            long c264693 = t26470;
            r942[i26468] = sub32(r940[c264690 * 16000 + c264692 * 16 + c264693 * 1], r941[c264690 * 1000 + c264692 * 1]);
        }
        /* max [max] -> r943 */
        for (long i26471 = 0; i26471 < 80000; ++i26471) {
            r943[i26471] = max32(r942[i26471], r14[0]);
        }
        /* reduce_sum [reduce_sum] -> r944 */
        for (long i26472 = 0; i26472 < 5000; ++i26472) {
            r944[i26472] = 0;
        }
        for (long i26473 = 0; i26473 < 80000; ++i26473) {
            long t26475 = i26473;
            long c264740 = t26475 / 16000; t26475 %= 16000;
            long c264741 = t26475 / 16000; t26475 %= 16000;
            long c264742 = t26475 / 16; t26475 %= 16;
            long c264743 = t26475;
            r944[c264740 * 1000 + c264741 * 1000 + c264742 * 1] = add32(r944[c264740 * 1000 + c264741 * 1000 + c264742 * 1], r943[i26473]);
        }
        /* add [add] -> r945 */
        for (long i26476 = 0; i26476 < 5000; ++i26476) {
            r945[i26476] = add32(r939[i26476], r944[i26476]);
        }
        /* gt [gt] -> r946 */
        for (long i26477 = 0; i26477 < 5000; ++i26477) {
            r946[i26477] = r945[i26477] > r929[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r947 */
        for (long i26478 = 0; i26478 < 5000; ++i26478) {
            r947[i26478] = r946[i26478] == 0 ? r931[i26478] : (r935[i26478]);
        }
        /* select_n [select_n] -> r948 */
        for (long i26479 = 0; i26479 < 5000; ++i26479) {
            r948[i26479] = r946[i26479] == 0 ? r935[i26479] : (r932[i26479]);
        }
        memcpy(r930, r933, sizeof(int32_t) * 1);
        memcpy(r931, r947, sizeof(int32_t) * 5000);
        memcpy(r932, r948, sizeof(int32_t) * 5000);
    }
    memcpy(r949, r930, sizeof(int32_t) * 1);
    memcpy(r950, r931, sizeof(int32_t) * 5000);
    memcpy(r951, r932, sizeof(int32_t) * 5000);
    /* abs [abs] -> r952 */
    for (long i26480 = 0; i26480 < 80000; ++i26480) {
        r952[i26480] = abs32(r924[i26480]);
    }
    /* reduce_max [reduce_max] -> r953 */
    for (long i26481 = 0; i26481 < 5000; ++i26481) {
        r953[i26481] = (-2147483647 - 1);
    }
    for (long i26482 = 0; i26482 < 80000; ++i26482) {
        long t26484 = i26482;
        long c264830 = t26484 / 16000; t26484 %= 16000;
        long c264831 = t26484 / 16000; t26484 %= 16000;
        long c264832 = t26484 / 16; t26484 %= 16;
        long c264833 = t26484;
        r953[c264830 * 1000 + c264831 * 1000 + c264832 * 1] = max32(r953[c264830 * 1000 + c264831 * 1000 + c264832 * 1], r952[i26482]);
    }
    /* sub [sub] -> r954 */
    for (long i26485 = 0; i26485 < 5000; ++i26485) {
        r954[i26485] = sub32(r953[i26485], r59[0]);
    }
    /* loop [scan] -> r976 */
    memcpy(r955, r924, sizeof(int32_t) * 80000);
    memcpy(r956, r59, sizeof(int32_t) * 1);
    memcpy(r957, r14, sizeof(int32_t) * 1);
    memcpy(r958, r954, sizeof(int32_t) * 5000);
    memcpy(r959, r953, sizeof(int32_t) * 5000);
    for (long t26486 = 0; t26486 < 12; ++t26486) {
        /* add [add] -> r960 */
        for (long i27487 = 0; i27487 < 1; ++i27487) {
            r960[i27487] = add32(r957[0], r9[0]);
        }
        /* add [add] -> r961 */
        for (long i27488 = 0; i27488 < 5000; ++i27488) {
            r961[i27488] = add32(r958[i27488], r959[i27488]);
        }
        /* shra [shift_right_arithmetic] -> r962 */
        for (long i27489 = 0; i27489 < 5000; ++i27489) {
            r962[i27489] = asr32(r961[i27489], 1);
        }
        /* broadcast [broadcast_in_dim] -> r963 */
        for (long i27490 = 0; i27490 < 5000; ++i27490) {
            long t27492 = i27490;
            long c274910 = t27492 / 1000; t27492 %= 1000;
            long c274911 = t27492 / 1000; t27492 %= 1000;
            long c274912 = t27492 / 1; t27492 %= 1;
            long c274913 = t27492;
            r963[i27490] = r962[c274910 * 1000 + c274912 * 1];
        }
        /* sub [sub] -> r964 */
        for (long i27493 = 0; i27493 < 80000; ++i27493) {
            long t27495 = i27493;
            long c274940 = t27495 / 16000; t27495 %= 16000;
            long c274941 = t27495 / 16000; t27495 %= 16000;
            long c274942 = t27495 / 16; t27495 %= 16;
            long c274943 = t27495;
            r964[i27493] = sub32(r955[c274940 * 16000 + c274942 * 16 + c274943 * 1], r963[c274940 * 1000 + c274942 * 1]);
        }
        /* max [max] -> r965 */
        for (long i27496 = 0; i27496 < 80000; ++i27496) {
            r965[i27496] = max32(r964[i27496], r14[0]);
        }
        /* reduce_sum [reduce_sum] -> r966 */
        for (long i27497 = 0; i27497 < 5000; ++i27497) {
            r966[i27497] = 0;
        }
        for (long i27498 = 0; i27498 < 80000; ++i27498) {
            long t27500 = i27498;
            long c274990 = t27500 / 16000; t27500 %= 16000;
            long c274991 = t27500 / 16000; t27500 %= 16000;
            long c274992 = t27500 / 16; t27500 %= 16;
            long c274993 = t27500;
            r966[c274990 * 1000 + c274991 * 1000 + c274992 * 1] = add32(r966[c274990 * 1000 + c274991 * 1000 + c274992 * 1], r965[i27498]);
        }
        /* neg [neg] -> r967 */
        for (long i27501 = 0; i27501 < 80000; ++i27501) {
            r967[i27501] = neg32(r955[i27501]);
        }
        /* broadcast [broadcast_in_dim] -> r968 */
        for (long i27502 = 0; i27502 < 5000; ++i27502) {
            long t27504 = i27502;
            long c275030 = t27504 / 1000; t27504 %= 1000;
            long c275031 = t27504 / 1000; t27504 %= 1000;
            long c275032 = t27504 / 1; t27504 %= 1;
            long c275033 = t27504;
            r968[i27502] = r962[c275030 * 1000 + c275032 * 1];
        }
        /* sub [sub] -> r969 */
        for (long i27505 = 0; i27505 < 80000; ++i27505) {
            long t27507 = i27505;
            long c275060 = t27507 / 16000; t27507 %= 16000;
            long c275061 = t27507 / 16000; t27507 %= 16000;
            long c275062 = t27507 / 16; t27507 %= 16;
            long c275063 = t27507;
            r969[i27505] = sub32(r967[c275060 * 16000 + c275062 * 16 + c275063 * 1], r968[c275060 * 1000 + c275062 * 1]);
        }
        /* max [max] -> r970 */
        for (long i27508 = 0; i27508 < 80000; ++i27508) {
            r970[i27508] = max32(r969[i27508], r14[0]);
        }
        /* reduce_sum [reduce_sum] -> r971 */
        for (long i27509 = 0; i27509 < 5000; ++i27509) {
            r971[i27509] = 0;
        }
        for (long i27510 = 0; i27510 < 80000; ++i27510) {
            long t27512 = i27510;
            long c275110 = t27512 / 16000; t27512 %= 16000;
            long c275111 = t27512 / 16000; t27512 %= 16000;
            long c275112 = t27512 / 16; t27512 %= 16;
            long c275113 = t27512;
            r971[c275110 * 1000 + c275111 * 1000 + c275112 * 1] = add32(r971[c275110 * 1000 + c275111 * 1000 + c275112 * 1], r970[i27510]);
        }
        /* add [add] -> r972 */
        for (long i27513 = 0; i27513 < 5000; ++i27513) {
            r972[i27513] = add32(r966[i27513], r971[i27513]);
        }
        /* gt [gt] -> r973 */
        for (long i27514 = 0; i27514 < 5000; ++i27514) {
            r973[i27514] = r972[i27514] > r956[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r974 */
        for (long i27515 = 0; i27515 < 5000; ++i27515) {
            r974[i27515] = r973[i27515] == 0 ? r958[i27515] : (r962[i27515]);
        }
        /* select_n [select_n] -> r975 */
        for (long i27516 = 0; i27516 < 5000; ++i27516) {
            r975[i27516] = r973[i27516] == 0 ? r962[i27516] : (r959[i27516]);
        }
        memcpy(r957, r960, sizeof(int32_t) * 1);
        memcpy(r958, r974, sizeof(int32_t) * 5000);
        memcpy(r959, r975, sizeof(int32_t) * 5000);
    }
    memcpy(r976, r957, sizeof(int32_t) * 1);
    memcpy(r977, r958, sizeof(int32_t) * 5000);
    memcpy(r978, r959, sizeof(int32_t) * 5000);
    /* sub [sub] -> r979 */
    for (long i27517 = 0; i27517 < 5000; ++i27517) {
        r979[i27517] = sub32(r951[i27517], r978[i27517]);
    }
    /* transpose [transpose] -> r980 */
    for (long i27518 = 0; i27518 < 5000; ++i27518) {
        long t27520 = i27518;
        long c275190 = t27520 / 5000; t27520 %= 5000;
        long c275191 = t27520 / 1000; t27520 %= 1000;
        long c275192 = t27520;
        r980[i27518] = r979[c275190 * 1000 + c275191 * 1000 + c275192 * 1];
    }
    /* max [max] -> r981 */
    for (long i27521 = 0; i27521 < 5000; ++i27521) {
        r981[i27521] = max32(r980[i27521], r14[0]);
    }
    /* reduce_sum [reduce_sum] -> r982 */
    for (long i27522 = 0; i27522 < 5; ++i27522) {
        r982[i27522] = 0;
    }
    for (long i27523 = 0; i27523 < 5000; ++i27523) {
        long t27525 = i27523;
        long c275240 = t27525 / 5000; t27525 %= 5000;
        long c275241 = t27525 / 1000; t27525 %= 1000;
        long c275242 = t27525;
        r982[c275240 * 5 + c275241 * 1] = add32(r982[c275240 * 5 + c275241 * 1], r981[i27523]);
    }
    /* shl [shift_left] -> r984 */
    for (long i27526 = 0; i27526 < 5; ++i27526) {
        r984[i27526] = shl32(r982[i27526], 4);
    }
    /* shl [shift_left] -> r985 */
    for (long i27527 = 0; i27527 < 1000; ++i27527) {
        r985[i27527] = shl32(r896[i27527], 1);
    }
    /* mov [device_put] -> r986 */
    memcpy(r986, r2, sizeof(int32_t) * 6);
    /* rev [rev] -> r987 */
    for (long i27528 = 0; i27528 < 6; ++i27528) {
        long t27530 = i27528;
        long c275290 = t27530 / 6; t27530 %= 6;
        long c275291 = t27530;
        r987[i27528] = r986[c275290 * 6 + (6 - 1 - c275291) * 1];
    }
    /* reshape [reshape] -> r988 */
    memcpy(r988, r987, sizeof(int32_t) * 6);
    /* convert [convert_element_type] -> r989 */
    for (long i27531 = 0; i27531 < 1; ++i27531) {
        r989[i27531] = (int32_t)r14[0];
    }
    /* pad [pad] -> r990 */
    for (long i27532 = 0; i27532 < 1005; ++i27532) {
        r990[i27532] = r989[0];
    }
    for (long i27533 = 0; i27533 < 1000; ++i27533) {
        long t27535 = i27533;
        long c275340 = t27535 / 1000; t27535 %= 1000;
        long c275341 = t27535;
        long d27536 = 0 + c275340 * 1;
        long d27537 = 5 + c275341 * 1;
        if (d27536 >= 0 && d27536 < 1 && d27537 >= 0 && d27537 < 1005) r990[d27536 * 1005 + d27537 * 1] = r985[i27533];
    }
    /* iota [iota] -> r991 */
    for (long i27538 = 0; i27538 < 1000; ++i27538) {
        long t27540 = i27538;
        long c275390 = t27540;
        r991[i27538] = (int32_t)c275390;
    }
    /* broadcast [broadcast_in_dim] -> r992 */
    for (long i27541 = 0; i27541 < 1000; ++i27541) {
        long t27543 = i27541;
        long c275420 = t27543 / 1; t27543 %= 1;
        long c275421 = t27543;
        r992[i27541] = r991[c275420 * 1];
    }
    /* iota [iota] -> r993 */
    for (long i27544 = 0; i27544 < 6; ++i27544) {
        long t27546 = i27544;
        long c275450 = t27546;
        r993[i27544] = (int32_t)c275450;
    }
    /* broadcast [broadcast_in_dim] -> r994 */
    for (long i27547 = 0; i27547 < 6; ++i27547) {
        long t27549 = i27547;
        long c275480 = t27549 / 6; t27549 %= 6;
        long c275481 = t27549;
        r994[i27547] = r993[c275481 * 1];
    }
    /* add [add] -> r995 */
    for (long i27550 = 0; i27550 < 6000; ++i27550) {
        long t27552 = i27550;
        long c275510 = t27552 / 6; t27552 %= 6;
        long c275511 = t27552;
        r995[i27550] = add32(r992[c275510 * 1], r994[c275511 * 1]);
    }
    /* lt [lt] -> r996 */
    for (long i27553 = 0; i27553 < 6000; ++i27553) {
        r996[i27553] = r995[i27553] < r14[0] ? 1 : 0;
    }
    /* add [add] -> r998 */
    for (long i27554 = 0; i27554 < 6000; ++i27554) {
        r998[i27554] = add32(r995[i27554], r997[0]);
    }
    /* select_n [select_n] -> r999 */
    for (long i27555 = 0; i27555 < 6000; ++i27555) {
        r999[i27555] = r996[i27555] == 0 ? r995[i27555] : (r998[i27555]);
    }
    /* broadcast [broadcast_in_dim] -> r1000 */
    for (long i27556 = 0; i27556 < 6000; ++i27556) {
        long t27558 = i27556;
        long c275570 = t27558 / 6; t27558 %= 6;
        long c275571 = t27558 / 1; t27558 %= 1;
        long c275572 = t27558;
        r1000[i27556] = r999[c275570 * 6 + c275571 * 1];
    }
    /* gather [gather] -> r1001 */
    for (long i27559 = 0; i27559 < 6000; ++i27559) {
        long t27561 = i27559;
        long c275600 = t27561 / 6000; t27561 %= 6000;
        long c275601 = t27561 / 6; t27561 %= 6;
        long c275602 = t27561;
        long row27562 = c275601 * 6 + c275602 * 1;
        long s27563 = clamp_start((long)r1000[row27562 + 0], 1005, 1);
        r1001[i27559] = r990[c275600 * 1005 + s27563 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r1002 */
    for (long i27564 = 0; i27564 < 6000; ++i27564) {
        long t27566 = i27564;
        long c275650 = t27566 / 6000; t27566 %= 6000;
        long c275651 = t27566 / 6000; t27566 %= 6000;
        long c275652 = t27566 / 6; t27566 %= 6;
        long c275653 = t27566;
        r1002[i27564] = r1001[c275652 * 6 + c275653 * 1];
    }
    /* add [add] -> r1003 */
    for (long i27567 = 0; i27567 < 6000; ++i27567) {
        long t27569 = i27567;
        long c275680 = t27569 / 6000; t27569 %= 6000;
        long c275681 = t27569 / 6000; t27569 %= 6000;
        long c275682 = t27569 / 6; t27569 %= 6;
        long c275683 = t27569;
        r1003[i27567] = add32(r988[c275683 * 1], r1002[c275682 * 6 + c275683 * 1]);
    }
    /* convert [convert_element_type] -> r1004 */
    for (long i27570 = 0; i27570 < 1; ++i27570) {
        r1004[i27570] = (int32_t)r46[0];
    }
    /* max [max] -> r1005 */
    for (long i27571 = 0; i27571 < 6000; ++i27571) {
        r1005[i27571] = max32(r1004[0], r1003[i27571]);
    }
    /* convert [convert_element_type] -> r1006 */
    for (long i27572 = 0; i27572 < 1; ++i27572) {
        r1006[i27572] = (int32_t)r47[0];
    }
    /* min [min] -> r1007 */
    for (long i27573 = 0; i27573 < 6000; ++i27573) {
        r1007[i27573] = min32(r1006[0], r1005[i27573]);
    }
    /* sub [sub] -> r1008 */
    for (long i27574 = 0; i27574 < 6000; ++i27574) {
        long t27576 = i27574;
        long c275750 = t27576 / 6000; t27576 %= 6000;
        long c275751 = t27576 / 6000; t27576 %= 6000;
        long c275752 = t27576 / 6; t27576 %= 6;
        long c275753 = t27576;
        r1008[i27574] = sub32(r988[c275753 * 1], r1002[c275752 * 6 + c275753 * 1]);
    }
    /* convert [convert_element_type] -> r1009 */
    for (long i27577 = 0; i27577 < 1; ++i27577) {
        r1009[i27577] = (int32_t)r46[0];
    }
    /* max [max] -> r1010 */
    for (long i27578 = 0; i27578 < 6000; ++i27578) {
        r1010[i27578] = max32(r1009[0], r1008[i27578]);
    }
    /* convert [convert_element_type] -> r1011 */
    for (long i27579 = 0; i27579 < 1; ++i27579) {
        r1011[i27579] = (int32_t)r47[0];
    }
    /* min [min] -> r1012 */
    for (long i27580 = 0; i27580 < 6000; ++i27580) {
        r1012[i27580] = min32(r1011[0], r1010[i27580]);
    }
    /* abs [abs] -> r1013 */
    for (long i27581 = 0; i27581 < 6000; ++i27581) {
        r1013[i27581] = abs32(r1007[i27581]);
    }
    /* reduce_max [reduce_max] -> r1014 */
    for (long i27582 = 0; i27582 < 1000; ++i27582) {
        r1014[i27582] = (-2147483647 - 1);
    }
    for (long i27583 = 0; i27583 < 6000; ++i27583) {
        long t27585 = i27583;
        long c275840 = t27585 / 6000; t27585 %= 6000;
        long c275841 = t27585 / 6000; t27585 %= 6000;
        long c275842 = t27585 / 6; t27585 %= 6;
        long c275843 = t27585;
        r1014[c275840 * 1000 + c275841 * 1000 + c275842 * 1] = max32(r1014[c275840 * 1000 + c275841 * 1000 + c275842 * 1], r1013[i27583]);
    }
    /* sub [sub] -> r1015 */
    for (long i27586 = 0; i27586 < 1000; ++i27586) {
        r1015[i27586] = sub32(r1014[i27586], r59[0]);
    }
    /* loop [scan] -> r1037 */
    memcpy(r1016, r1007, sizeof(int32_t) * 6000);
    memcpy(r1017, r59, sizeof(int32_t) * 1);
    memcpy(r1018, r14, sizeof(int32_t) * 1);
    memcpy(r1019, r1015, sizeof(int32_t) * 1000);
    memcpy(r1020, r1014, sizeof(int32_t) * 1000);
    for (long t27587 = 0; t27587 < 12; ++t27587) {
        /* add [add] -> r1021 */
        for (long i28588 = 0; i28588 < 1; ++i28588) {
            r1021[i28588] = add32(r1018[0], r9[0]);
        }
        /* add [add] -> r1022 */
        for (long i28589 = 0; i28589 < 1000; ++i28589) {
            r1022[i28589] = add32(r1019[i28589], r1020[i28589]);
        }
        /* shra [shift_right_arithmetic] -> r1023 */
        for (long i28590 = 0; i28590 < 1000; ++i28590) {
            r1023[i28590] = asr32(r1022[i28590], 1);
        }
        /* broadcast [broadcast_in_dim] -> r1024 */
        for (long i28591 = 0; i28591 < 1000; ++i28591) {
            long t28593 = i28591;
            long c285920 = t28593 / 1000; t28593 %= 1000;
            long c285921 = t28593 / 1000; t28593 %= 1000;
            long c285922 = t28593 / 1; t28593 %= 1;
            long c285923 = t28593;
            r1024[i28591] = r1023[c285922 * 1];
        }
        /* sub [sub] -> r1025 */
        for (long i28594 = 0; i28594 < 6000; ++i28594) {
            long t28596 = i28594;
            long c285950 = t28596 / 6000; t28596 %= 6000;
            long c285951 = t28596 / 6000; t28596 %= 6000;
            long c285952 = t28596 / 6; t28596 %= 6;
            long c285953 = t28596;
            r1025[i28594] = sub32(r1016[c285952 * 6 + c285953 * 1], r1024[c285952 * 1]);
        }
        /* max [max] -> r1026 */
        for (long i28597 = 0; i28597 < 6000; ++i28597) {
            r1026[i28597] = max32(r1025[i28597], r14[0]);
        }
        /* reduce_sum [reduce_sum] -> r1027 */
        for (long i28598 = 0; i28598 < 1000; ++i28598) {
            r1027[i28598] = 0;
        }
        for (long i28599 = 0; i28599 < 6000; ++i28599) {
            long t28601 = i28599;
            long c286000 = t28601 / 6000; t28601 %= 6000;
            long c286001 = t28601 / 6000; t28601 %= 6000;
            long c286002 = t28601 / 6; t28601 %= 6;
            long c286003 = t28601;
            r1027[c286000 * 1000 + c286001 * 1000 + c286002 * 1] = add32(r1027[c286000 * 1000 + c286001 * 1000 + c286002 * 1], r1026[i28599]);
        }
        /* neg [neg] -> r1028 */
        for (long i28602 = 0; i28602 < 6000; ++i28602) {
            r1028[i28602] = neg32(r1016[i28602]);
        }
        /* broadcast [broadcast_in_dim] -> r1029 */
        for (long i28603 = 0; i28603 < 1000; ++i28603) {
            long t28605 = i28603;
            long c286040 = t28605 / 1000; t28605 %= 1000;
            long c286041 = t28605 / 1000; t28605 %= 1000;
            long c286042 = t28605 / 1; t28605 %= 1;
            long c286043 = t28605;
            r1029[i28603] = r1023[c286042 * 1];
        }
        /* sub [sub] -> r1030 */
        for (long i28606 = 0; i28606 < 6000; ++i28606) {
            long t28608 = i28606;
            long c286070 = t28608 / 6000; t28608 %= 6000;
            long c286071 = t28608 / 6000; t28608 %= 6000;
            long c286072 = t28608 / 6; t28608 %= 6;
            long c286073 = t28608;
            r1030[i28606] = sub32(r1028[c286072 * 6 + c286073 * 1], r1029[c286072 * 1]);
        }
        /* max [max] -> r1031 */
        for (long i28609 = 0; i28609 < 6000; ++i28609) {
            r1031[i28609] = max32(r1030[i28609], r14[0]);
        }
        /* reduce_sum [reduce_sum] -> r1032 */
        for (long i28610 = 0; i28610 < 1000; ++i28610) {
            r1032[i28610] = 0;
        }
        for (long i28611 = 0; i28611 < 6000; ++i28611) {
            long t28613 = i28611;
            long c286120 = t28613 / 6000; t28613 %= 6000;
            long c286121 = t28613 / 6000; t28613 %= 6000;
            long c286122 = t28613 / 6; t28613 %= 6;
            long c286123 = t28613;
            r1032[c286120 * 1000 + c286121 * 1000 + c286122 * 1] = add32(r1032[c286120 * 1000 + c286121 * 1000 + c286122 * 1], r1031[i28611]);
        }
        /* add [add] -> r1033 */
        for (long i28614 = 0; i28614 < 1000; ++i28614) {
            r1033[i28614] = add32(r1027[i28614], r1032[i28614]);
        }
        /* gt [gt] -> r1034 */
        for (long i28615 = 0; i28615 < 1000; ++i28615) {
            r1034[i28615] = r1033[i28615] > r1017[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r1035 */
        for (long i28616 = 0; i28616 < 1000; ++i28616) {
            r1035[i28616] = r1034[i28616] == 0 ? r1019[i28616] : (r1023[i28616]);
        }
        /* select_n [select_n] -> r1036 */
        for (long i28617 = 0; i28617 < 1000; ++i28617) {
            r1036[i28617] = r1034[i28617] == 0 ? r1023[i28617] : (r1020[i28617]);
        }
        memcpy(r1018, r1021, sizeof(int32_t) * 1);
        memcpy(r1019, r1035, sizeof(int32_t) * 1000);
        memcpy(r1020, r1036, sizeof(int32_t) * 1000);
    }
    memcpy(r1037, r1018, sizeof(int32_t) * 1);
    memcpy(r1038, r1019, sizeof(int32_t) * 1000);
    memcpy(r1039, r1020, sizeof(int32_t) * 1000);
    /* abs [abs] -> r1040 */
    for (long i28618 = 0; i28618 < 6000; ++i28618) {
        r1040[i28618] = abs32(r1012[i28618]);
    }
    /* reduce_max [reduce_max] -> r1041 */
    for (long i28619 = 0; i28619 < 1000; ++i28619) {
        r1041[i28619] = (-2147483647 - 1);
    }
    for (long i28620 = 0; i28620 < 6000; ++i28620) {
        long t28622 = i28620;
        long c286210 = t28622 / 6000; t28622 %= 6000;
        long c286211 = t28622 / 6000; t28622 %= 6000;
        long c286212 = t28622 / 6; t28622 %= 6;
        long c286213 = t28622;
        r1041[c286210 * 1000 + c286211 * 1000 + c286212 * 1] = max32(r1041[c286210 * 1000 + c286211 * 1000 + c286212 * 1], r1040[i28620]);
    }
    /* sub [sub] -> r1042 */
    for (long i28623 = 0; i28623 < 1000; ++i28623) {
        r1042[i28623] = sub32(r1041[i28623], r59[0]);
    }
    /* loop [scan] -> r1064 */
    memcpy(r1043, r1012, sizeof(int32_t) * 6000);
    memcpy(r1044, r59, sizeof(int32_t) * 1);
    memcpy(r1045, r14, sizeof(int32_t) * 1);
    memcpy(r1046, r1042, sizeof(int32_t) * 1000);
    memcpy(r1047, r1041, sizeof(int32_t) * 1000);
    for (long t28624 = 0; t28624 < 12; ++t28624) {
        /* add [add] -> r1048 */
        for (long i29625 = 0; i29625 < 1; ++i29625) {
            r1048[i29625] = add32(r1045[0], r9[0]);
        }
        /* add [add] -> r1049 */
        for (long i29626 = 0; i29626 < 1000; ++i29626) {
            r1049[i29626] = add32(r1046[i29626], r1047[i29626]);
        }
        /* shra [shift_right_arithmetic] -> r1050 */
        for (long i29627 = 0; i29627 < 1000; ++i29627) {
            r1050[i29627] = asr32(r1049[i29627], 1);
        }
        /* broadcast [broadcast_in_dim] -> r1051 */
        for (long i29628 = 0; i29628 < 1000; ++i29628) {
            long t29630 = i29628;
            long c296290 = t29630 / 1000; t29630 %= 1000;
            long c296291 = t29630 / 1000; t29630 %= 1000;
            long c296292 = t29630 / 1; t29630 %= 1;
            long c296293 = t29630;
            r1051[i29628] = r1050[c296292 * 1];
        }
        /* sub [sub] -> r1052 */
        for (long i29631 = 0; i29631 < 6000; ++i29631) {
            long t29633 = i29631;
            long c296320 = t29633 / 6000; t29633 %= 6000;
            long c296321 = t29633 / 6000; t29633 %= 6000;
            long c296322 = t29633 / 6; t29633 %= 6;
            long c296323 = t29633;
            r1052[i29631] = sub32(r1043[c296322 * 6 + c296323 * 1], r1051[c296322 * 1]);
        }
        /* max [max] -> r1053 */
        for (long i29634 = 0; i29634 < 6000; ++i29634) {
            r1053[i29634] = max32(r1052[i29634], r14[0]);
        }
        /* reduce_sum [reduce_sum] -> r1054 */
        for (long i29635 = 0; i29635 < 1000; ++i29635) {
            r1054[i29635] = 0;
        }
        for (long i29636 = 0; i29636 < 6000; ++i29636) {
            long t29638 = i29636;
            long c296370 = t29638 / 6000; t29638 %= 6000;
            long c296371 = t29638 / 6000; t29638 %= 6000;
            long c296372 = t29638 / 6; t29638 %= 6;
            long c296373 = t29638;
            r1054[c296370 * 1000 + c296371 * 1000 + c296372 * 1] = add32(r1054[c296370 * 1000 + c296371 * 1000 + c296372 * 1], r1053[i29636]);
        }
        /* neg [neg] -> r1055 */
        for (long i29639 = 0; i29639 < 6000; ++i29639) {
            r1055[i29639] = neg32(r1043[i29639]);
        }
        /* broadcast [broadcast_in_dim] -> r1056 */
        for (long i29640 = 0; i29640 < 1000; ++i29640) {
            long t29642 = i29640;
            long c296410 = t29642 / 1000; t29642 %= 1000;
            long c296411 = t29642 / 1000; t29642 %= 1000;
            long c296412 = t29642 / 1; t29642 %= 1;
            long c296413 = t29642;
            r1056[i29640] = r1050[c296412 * 1];
        }
        /* sub [sub] -> r1057 */
        for (long i29643 = 0; i29643 < 6000; ++i29643) {
            long t29645 = i29643;
            long c296440 = t29645 / 6000; t29645 %= 6000;
            long c296441 = t29645 / 6000; t29645 %= 6000;
            long c296442 = t29645 / 6; t29645 %= 6;
            long c296443 = t29645;
            r1057[i29643] = sub32(r1055[c296442 * 6 + c296443 * 1], r1056[c296442 * 1]);
        }
        /* max [max] -> r1058 */
        for (long i29646 = 0; i29646 < 6000; ++i29646) {
            r1058[i29646] = max32(r1057[i29646], r14[0]);
        }
        /* reduce_sum [reduce_sum] -> r1059 */
        for (long i29647 = 0; i29647 < 1000; ++i29647) {
            r1059[i29647] = 0;
        }
        for (long i29648 = 0; i29648 < 6000; ++i29648) {
            long t29650 = i29648;
            long c296490 = t29650 / 6000; t29650 %= 6000;
            long c296491 = t29650 / 6000; t29650 %= 6000;
            long c296492 = t29650 / 6; t29650 %= 6;
            long c296493 = t29650;
            r1059[c296490 * 1000 + c296491 * 1000 + c296492 * 1] = add32(r1059[c296490 * 1000 + c296491 * 1000 + c296492 * 1], r1058[i29648]);
        }
        /* add [add] -> r1060 */
        for (long i29651 = 0; i29651 < 1000; ++i29651) {
            r1060[i29651] = add32(r1054[i29651], r1059[i29651]);
        }
        /* gt [gt] -> r1061 */
        for (long i29652 = 0; i29652 < 1000; ++i29652) {
            r1061[i29652] = r1060[i29652] > r1044[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r1062 */
        for (long i29653 = 0; i29653 < 1000; ++i29653) {
            r1062[i29653] = r1061[i29653] == 0 ? r1046[i29653] : (r1050[i29653]);
        }
        /* select_n [select_n] -> r1063 */
        for (long i29654 = 0; i29654 < 1000; ++i29654) {
            r1063[i29654] = r1061[i29654] == 0 ? r1050[i29654] : (r1047[i29654]);
        }
        memcpy(r1045, r1048, sizeof(int32_t) * 1);
        memcpy(r1046, r1062, sizeof(int32_t) * 1000);
        memcpy(r1047, r1063, sizeof(int32_t) * 1000);
    }
    memcpy(r1064, r1045, sizeof(int32_t) * 1);
    memcpy(r1065, r1046, sizeof(int32_t) * 1000);
    memcpy(r1066, r1047, sizeof(int32_t) * 1000);
    /* sub [sub] -> r1067 */
    for (long i29655 = 0; i29655 < 1000; ++i29655) {
        r1067[i29655] = sub32(r1039[i29655], r1066[i29655]);
    }
    /* transpose [transpose] -> r1068 */
    for (long i29656 = 0; i29656 < 1000; ++i29656) {
        long t29658 = i29656;
        long c296570 = t29658 / 1000; t29658 %= 1000;
        long c296571 = t29658 / 1000; t29658 %= 1000;
        long c296572 = t29658;
        r1068[i29656] = r1067[c296570 * 1000 + c296571 * 1000 + c296572 * 1];
    }
    /* slice [slice] -> r1069 */
    for (long i29659 = 0; i29659 < 1000; ++i29659) {
        long t29661 = i29659;
        long c296600 = t29661 / 1000; t29661 %= 1000;
        long c296601 = t29661 / 1000; t29661 %= 1000;
        long c296602 = t29661;
        r1069[i29659] = r1068[(0 + c296600 * 1) * 1000 + (0 + c296601 * 1) * 1000 + (0 + c296602 * 1) * 1];
    }
    /* reshape [squeeze] -> r1070 */
    memcpy(r1070, r1069, sizeof(int32_t) * 1000);
    /* shra [shift_right_arithmetic] -> r1071 */
    for (long i29662 = 0; i29662 < 1000; ++i29662) {
        r1071[i29662] = asr32(r1070[i29662], 1);
    }
    /* convert [convert_element_type] -> r1072 */
    for (long i29663 = 0; i29663 < 1; ++i29663) {
        r1072[i29663] = (int32_t)r227[0];
    }
    /* max [max] -> r1073 */
    for (long i29664 = 0; i29664 < 1000; ++i29664) {
        r1073[i29664] = max32(r1072[0], r1071[i29664]);
    }
    /* convert [convert_element_type] -> r1074 */
    for (long i29665 = 0; i29665 < 1; ++i29665) {
        r1074[i29665] = (int32_t)r228[0];
    }
    /* min [min] -> r1075 */
    for (long i29666 = 0; i29666 < 1000; ++i29666) {
        r1075[i29666] = min32(r1074[0], r1073[i29666]);
    }
    /* iota [iota] -> r1076 */
    for (long i29667 = 0; i29667 < 500; ++i29667) {
        long t29669 = i29667;
        long c296680 = t29669;
        r1076[i29667] = (int32_t)c296680;
    }
    /* shl [mul] -> r1077 */
    for (long i29670 = 0; i29670 < 500; ++i29670) {
        r1077[i29670] = shl32(r1076[i29670], 1);
    }
    /* add [add] -> r1078 */
    for (long i29671 = 0; i29671 < 500; ++i29671) {
        r1078[i29671] = add32(r14[0], r1077[i29671]);
    }
    /* broadcast [broadcast_in_dim] -> r1079 */
    for (long i29672 = 0; i29672 < 500; ++i29672) {
        long t29674 = i29672;
        long c296730 = t29674 / 1; t29674 %= 1;
        long c296731 = t29674;
        r1079[i29672] = r1078[c296730 * 1];
    }
    /* gather [gather] -> r1080 */
    for (long i29675 = 0; i29675 < 500; ++i29675) {
        long t29677 = i29675;
        long c296760 = t29677 / 500; t29677 %= 500;
        long c296761 = t29677;
        long row29678 = c296761 * 1;
        long s29679 = clamp_start((long)r1079[row29678 + 0], 1000, 1);
        r1080[i29675] = r1075[c296760 * 1000 + s29679 * 1];
    }
    /* shl [shift_left] -> r1081 */
    for (long i29680 = 0; i29680 < 500; ++i29680) {
        r1081[i29680] = shl32(r1080[i29680], 1);
    }
    /* mov [device_put] -> r1082 */
    memcpy(r1082, r1, sizeof(int32_t) * 80);
    /* rev [rev] -> r1083 */
    for (long i29681 = 0; i29681 < 80; ++i29681) {
        long t29683 = i29681;
        long c296820 = t29683 / 16; t29683 %= 16;
        long c296821 = t29683;
        r1083[i29681] = r1082[c296820 * 16 + (16 - 1 - c296821) * 1];
    }
    /* reshape [reshape] -> r1084 */
    memcpy(r1084, r1083, sizeof(int32_t) * 80);
    /* convert [convert_element_type] -> r1085 */
    for (long i29684 = 0; i29684 < 1; ++i29684) {
        r1085[i29684] = (int32_t)r14[0];
    }
    /* pad [pad] -> r1086 */
    for (long i29685 = 0; i29685 < 515; ++i29685) {
        r1086[i29685] = r1085[0];
    }
    for (long i29686 = 0; i29686 < 500; ++i29686) {
        long t29688 = i29686;
        long c296870 = t29688 / 500; t29688 %= 500;
        long c296871 = t29688;
        long d29689 = 0 + c296870 * 1;
        long d29690 = 15 + c296871 * 1;
        if (d29689 >= 0 && d29689 < 1 && d29690 >= 0 && d29690 < 515) r1086[d29689 * 515 + d29690 * 1] = r1081[i29686];
    }
    /* iota [iota] -> r1087 */
    for (long i29691 = 0; i29691 < 500; ++i29691) {
        long t29693 = i29691;
        long c296920 = t29693;
        r1087[i29691] = (int32_t)c296920;
    }
    /* broadcast [broadcast_in_dim] -> r1088 */
    for (long i29694 = 0; i29694 < 500; ++i29694) {
        long t29696 = i29694;
        long c296950 = t29696 / 1; t29696 %= 1;
        long c296951 = t29696;
        r1088[i29694] = r1087[c296950 * 1];
    }
    /* iota [iota] -> r1089 */
    for (long i29697 = 0; i29697 < 16; ++i29697) {
        long t29699 = i29697;
        long c296980 = t29699;
        r1089[i29697] = (int32_t)c296980;
    }
    /* broadcast [broadcast_in_dim] -> r1090 */
    for (long i29700 = 0; i29700 < 16; ++i29700) {
        long t29702 = i29700;
        long c297010 = t29702 / 16; t29702 %= 16;
        long c297011 = t29702;
        r1090[i29700] = r1089[c297011 * 1];
    }
    /* add [add] -> r1091 */
    for (long i29703 = 0; i29703 < 8000; ++i29703) {
        long t29705 = i29703;
        long c297040 = t29705 / 16; t29705 %= 16;
        long c297041 = t29705;
        r1091[i29703] = add32(r1088[c297040 * 1], r1090[c297041 * 1]);
    }
    /* lt [lt] -> r1092 */
    for (long i29706 = 0; i29706 < 8000; ++i29706) {
        r1092[i29706] = r1091[i29706] < r14[0] ? 1 : 0;
    }
    /* add [add] -> r1094 */
    for (long i29707 = 0; i29707 < 8000; ++i29707) {
        r1094[i29707] = add32(r1091[i29707], r1093[0]);
    }
    /* select_n [select_n] -> r1095 */
    for (long i29708 = 0; i29708 < 8000; ++i29708) {
        r1095[i29708] = r1092[i29708] == 0 ? r1091[i29708] : (r1094[i29708]);
    }
    /* broadcast [broadcast_in_dim] -> r1096 */
    for (long i29709 = 0; i29709 < 8000; ++i29709) {
        long t29711 = i29709;
        long c297100 = t29711 / 16; t29711 %= 16;
        long c297101 = t29711 / 1; t29711 %= 1;
        long c297102 = t29711;
        r1096[i29709] = r1095[c297100 * 16 + c297101 * 1];
    }
    /* gather [gather] -> r1097 */
    for (long i29712 = 0; i29712 < 8000; ++i29712) {
        long t29714 = i29712;
        long c297130 = t29714 / 8000; t29714 %= 8000;
        long c297131 = t29714 / 16; t29714 %= 16;
        long c297132 = t29714;
        long row29715 = c297131 * 16 + c297132 * 1;
        long s29716 = clamp_start((long)r1096[row29715 + 0], 515, 1);
        r1097[i29712] = r1086[c297130 * 515 + s29716 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r1098 */
    for (long i29717 = 0; i29717 < 8000; ++i29717) {
        long t29719 = i29717;
        long c297180 = t29719 / 8000; t29719 %= 8000;
        long c297181 = t29719 / 8000; t29719 %= 8000;
        long c297182 = t29719 / 16; t29719 %= 16;
        long c297183 = t29719;
        r1098[i29717] = r1097[c297182 * 16 + c297183 * 1];
    }
    /* add [add] -> r1099 */
    for (long i29720 = 0; i29720 < 40000; ++i29720) {
        long t29722 = i29720;
        long c297210 = t29722 / 8000; t29722 %= 8000;
        long c297211 = t29722 / 8000; t29722 %= 8000;
        long c297212 = t29722 / 16; t29722 %= 16;
        long c297213 = t29722;
        r1099[i29720] = add32(r1084[c297210 * 16 + c297213 * 1], r1098[c297212 * 16 + c297213 * 1]);
    }
    /* convert [convert_element_type] -> r1100 */
    for (long i29723 = 0; i29723 < 1; ++i29723) {
        r1100[i29723] = (int32_t)r46[0];
    }
    /* max [max] -> r1101 */
    for (long i29724 = 0; i29724 < 40000; ++i29724) {
        r1101[i29724] = max32(r1100[0], r1099[i29724]);
    }
    /* convert [convert_element_type] -> r1102 */
    for (long i29725 = 0; i29725 < 1; ++i29725) {
        r1102[i29725] = (int32_t)r47[0];
    }
    /* min [min] -> r1103 */
    for (long i29726 = 0; i29726 < 40000; ++i29726) {
        r1103[i29726] = min32(r1102[0], r1101[i29726]);
    }
    /* sub [sub] -> r1104 */
    for (long i29727 = 0; i29727 < 40000; ++i29727) {
        long t29729 = i29727;
        long c297280 = t29729 / 8000; t29729 %= 8000;
        long c297281 = t29729 / 8000; t29729 %= 8000;
        long c297282 = t29729 / 16; t29729 %= 16;
        long c297283 = t29729;
        r1104[i29727] = sub32(r1084[c297280 * 16 + c297283 * 1], r1098[c297282 * 16 + c297283 * 1]);
    }
    /* convert [convert_element_type] -> r1105 */
    for (long i29730 = 0; i29730 < 1; ++i29730) {
        r1105[i29730] = (int32_t)r46[0];
    }
    /* max [max] -> r1106 */
    for (long i29731 = 0; i29731 < 40000; ++i29731) {
        r1106[i29731] = max32(r1105[0], r1104[i29731]);
    }
    /* convert [convert_element_type] -> r1107 */
    for (long i29732 = 0; i29732 < 1; ++i29732) {
        r1107[i29732] = (int32_t)r47[0];
    }
    /* min [min] -> r1108 */
    for (long i29733 = 0; i29733 < 40000; ++i29733) {
        r1108[i29733] = min32(r1107[0], r1106[i29733]);
    }
    /* abs [abs] -> r1109 */
    for (long i29734 = 0; i29734 < 40000; ++i29734) {
        r1109[i29734] = abs32(r1103[i29734]);
    }
    /* reduce_max [reduce_max] -> r1110 */
    for (long i29735 = 0; i29735 < 2500; ++i29735) {
        r1110[i29735] = (-2147483647 - 1);
    }
    for (long i29736 = 0; i29736 < 40000; ++i29736) {
        long t29738 = i29736;
        long c297370 = t29738 / 8000; t29738 %= 8000;
        long c297371 = t29738 / 8000; t29738 %= 8000;
        long c297372 = t29738 / 16; t29738 %= 16;
        long c297373 = t29738;
        r1110[c297370 * 500 + c297371 * 500 + c297372 * 1] = max32(r1110[c297370 * 500 + c297371 * 500 + c297372 * 1], r1109[i29736]);
    }
    /* sub [sub] -> r1111 */
    for (long i29739 = 0; i29739 < 2500; ++i29739) {
        r1111[i29739] = sub32(r1110[i29739], r59[0]);
    }
    /* loop [scan] -> r1133 */
    memcpy(r1112, r1103, sizeof(int32_t) * 40000);
    memcpy(r1113, r59, sizeof(int32_t) * 1);
    memcpy(r1114, r14, sizeof(int32_t) * 1);
    memcpy(r1115, r1111, sizeof(int32_t) * 2500);
    memcpy(r1116, r1110, sizeof(int32_t) * 2500);
    for (long t29740 = 0; t29740 < 12; ++t29740) {
        /* add [add] -> r1117 */
        for (long i30741 = 0; i30741 < 1; ++i30741) {
            r1117[i30741] = add32(r1114[0], r9[0]);
        }
        /* add [add] -> r1118 */
        for (long i30742 = 0; i30742 < 2500; ++i30742) {
            r1118[i30742] = add32(r1115[i30742], r1116[i30742]);
        }
        /* shra [shift_right_arithmetic] -> r1119 */
        for (long i30743 = 0; i30743 < 2500; ++i30743) {
            r1119[i30743] = asr32(r1118[i30743], 1);
        }
        /* broadcast [broadcast_in_dim] -> r1120 */
        for (long i30744 = 0; i30744 < 2500; ++i30744) {
            long t30746 = i30744;
            long c307450 = t30746 / 500; t30746 %= 500;
            long c307451 = t30746 / 500; t30746 %= 500;
            long c307452 = t30746 / 1; t30746 %= 1;
            long c307453 = t30746;
            r1120[i30744] = r1119[c307450 * 500 + c307452 * 1];
        }
        /* sub [sub] -> r1121 */
        for (long i30747 = 0; i30747 < 40000; ++i30747) {
            long t30749 = i30747;
            long c307480 = t30749 / 8000; t30749 %= 8000;
            long c307481 = t30749 / 8000; t30749 %= 8000;
            long c307482 = t30749 / 16; t30749 %= 16;
            long c307483 = t30749;
            r1121[i30747] = sub32(r1112[c307480 * 8000 + c307482 * 16 + c307483 * 1], r1120[c307480 * 500 + c307482 * 1]);
        }
        /* max [max] -> r1122 */
        for (long i30750 = 0; i30750 < 40000; ++i30750) {
            r1122[i30750] = max32(r1121[i30750], r14[0]);
        }
        /* reduce_sum [reduce_sum] -> r1123 */
        for (long i30751 = 0; i30751 < 2500; ++i30751) {
            r1123[i30751] = 0;
        }
        for (long i30752 = 0; i30752 < 40000; ++i30752) {
            long t30754 = i30752;
            long c307530 = t30754 / 8000; t30754 %= 8000;
            long c307531 = t30754 / 8000; t30754 %= 8000;
            long c307532 = t30754 / 16; t30754 %= 16;
            long c307533 = t30754;
            r1123[c307530 * 500 + c307531 * 500 + c307532 * 1] = add32(r1123[c307530 * 500 + c307531 * 500 + c307532 * 1], r1122[i30752]);
        }
        /* neg [neg] -> r1124 */
        for (long i30755 = 0; i30755 < 40000; ++i30755) {
            r1124[i30755] = neg32(r1112[i30755]);
        }
        /* broadcast [broadcast_in_dim] -> r1125 */
        for (long i30756 = 0; i30756 < 2500; ++i30756) {
            long t30758 = i30756;
            long c307570 = t30758 / 500; t30758 %= 500;
            long c307571 = t30758 / 500; t30758 %= 500;
            long c307572 = t30758 / 1; t30758 %= 1;
            long c307573 = t30758;
            r1125[i30756] = r1119[c307570 * 500 + c307572 * 1];
        }
        /* sub [sub] -> r1126 */
        for (long i30759 = 0; i30759 < 40000; ++i30759) {
            long t30761 = i30759;
            long c307600 = t30761 / 8000; t30761 %= 8000;
            long c307601 = t30761 / 8000; t30761 %= 8000;
            long c307602 = t30761 / 16; t30761 %= 16;
            long c307603 = t30761;
            r1126[i30759] = sub32(r1124[c307600 * 8000 + c307602 * 16 + c307603 * 1], r1125[c307600 * 500 + c307602 * 1]);
        }
        /* max [max] -> r1127 */
        for (long i30762 = 0; i30762 < 40000; ++i30762) {
            r1127[i30762] = max32(r1126[i30762], r14[0]);
        }
        /* reduce_sum [reduce_sum] -> r1128 */
        for (long i30763 = 0; i30763 < 2500; ++i30763) {
            r1128[i30763] = 0;
        }
        for (long i30764 = 0; i30764 < 40000; ++i30764) {
            long t30766 = i30764;
            long c307650 = t30766 / 8000; t30766 %= 8000;
            long c307651 = t30766 / 8000; t30766 %= 8000;
            long c307652 = t30766 / 16; t30766 %= 16;
            long c307653 = t30766;
            r1128[c307650 * 500 + c307651 * 500 + c307652 * 1] = add32(r1128[c307650 * 500 + c307651 * 500 + c307652 * 1], r1127[i30764]);
        }
        /* add [add] -> r1129 */
        for (long i30767 = 0; i30767 < 2500; ++i30767) {
            r1129[i30767] = add32(r1123[i30767], r1128[i30767]);
        }
        /* gt [gt] -> r1130 */
        for (long i30768 = 0; i30768 < 2500; ++i30768) {
            r1130[i30768] = r1129[i30768] > r1113[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r1131 */
        for (long i30769 = 0; i30769 < 2500; ++i30769) {
            r1131[i30769] = r1130[i30769] == 0 ? r1115[i30769] : (r1119[i30769]);
        }
        /* select_n [select_n] -> r1132 */
        for (long i30770 = 0; i30770 < 2500; ++i30770) {
            r1132[i30770] = r1130[i30770] == 0 ? r1119[i30770] : (r1116[i30770]);
        }
        memcpy(r1114, r1117, sizeof(int32_t) * 1);
        memcpy(r1115, r1131, sizeof(int32_t) * 2500);
        memcpy(r1116, r1132, sizeof(int32_t) * 2500);
    }
    memcpy(r1133, r1114, sizeof(int32_t) * 1);
    memcpy(r1134, r1115, sizeof(int32_t) * 2500);
    memcpy(r1135, r1116, sizeof(int32_t) * 2500);
    /* abs [abs] -> r1136 */
    for (long i30771 = 0; i30771 < 40000; ++i30771) {
        r1136[i30771] = abs32(r1108[i30771]);
    }
    /* reduce_max [reduce_max] -> r1137 */
    for (long i30772 = 0; i30772 < 2500; ++i30772) {
        r1137[i30772] = (-2147483647 - 1);
    }
    for (long i30773 = 0; i30773 < 40000; ++i30773) {
        long t30775 = i30773;
        long c307740 = t30775 / 8000; t30775 %= 8000;
        long c307741 = t30775 / 8000; t30775 %= 8000;
        long c307742 = t30775 / 16; t30775 %= 16;
        long c307743 = t30775;
        r1137[c307740 * 500 + c307741 * 500 + c307742 * 1] = max32(r1137[c307740 * 500 + c307741 * 500 + c307742 * 1], r1136[i30773]);
    }
    /* sub [sub] -> r1138 */
    for (long i30776 = 0; i30776 < 2500; ++i30776) {
        r1138[i30776] = sub32(r1137[i30776], r59[0]);
    }
    /* loop [scan] -> r1160 */
    memcpy(r1139, r1108, sizeof(int32_t) * 40000);
    memcpy(r1140, r59, sizeof(int32_t) * 1);
    memcpy(r1141, r14, sizeof(int32_t) * 1);
    memcpy(r1142, r1138, sizeof(int32_t) * 2500);
    memcpy(r1143, r1137, sizeof(int32_t) * 2500);
    for (long t30777 = 0; t30777 < 12; ++t30777) {
        /* add [add] -> r1144 */
        for (long i31778 = 0; i31778 < 1; ++i31778) {
            r1144[i31778] = add32(r1141[0], r9[0]);
        }
        /* add [add] -> r1145 */
        for (long i31779 = 0; i31779 < 2500; ++i31779) {
            r1145[i31779] = add32(r1142[i31779], r1143[i31779]);
        }
        /* shra [shift_right_arithmetic] -> r1146 */
        for (long i31780 = 0; i31780 < 2500; ++i31780) {
            r1146[i31780] = asr32(r1145[i31780], 1);
        }
        /* broadcast [broadcast_in_dim] -> r1147 */
        for (long i31781 = 0; i31781 < 2500; ++i31781) {
            long t31783 = i31781;
            long c317820 = t31783 / 500; t31783 %= 500;
            long c317821 = t31783 / 500; t31783 %= 500;
            long c317822 = t31783 / 1; t31783 %= 1;
            long c317823 = t31783;
            r1147[i31781] = r1146[c317820 * 500 + c317822 * 1];
        }
        /* sub [sub] -> r1148 */
        for (long i31784 = 0; i31784 < 40000; ++i31784) {
            long t31786 = i31784;
            long c317850 = t31786 / 8000; t31786 %= 8000;
            long c317851 = t31786 / 8000; t31786 %= 8000;
            long c317852 = t31786 / 16; t31786 %= 16;
            long c317853 = t31786;
            r1148[i31784] = sub32(r1139[c317850 * 8000 + c317852 * 16 + c317853 * 1], r1147[c317850 * 500 + c317852 * 1]);
        }
        /* max [max] -> r1149 */
        for (long i31787 = 0; i31787 < 40000; ++i31787) {
            r1149[i31787] = max32(r1148[i31787], r14[0]);
        }
        /* reduce_sum [reduce_sum] -> r1150 */
        for (long i31788 = 0; i31788 < 2500; ++i31788) {
            r1150[i31788] = 0;
        }
        for (long i31789 = 0; i31789 < 40000; ++i31789) {
            long t31791 = i31789;
            long c317900 = t31791 / 8000; t31791 %= 8000;
            long c317901 = t31791 / 8000; t31791 %= 8000;
            long c317902 = t31791 / 16; t31791 %= 16;
            long c317903 = t31791;
            r1150[c317900 * 500 + c317901 * 500 + c317902 * 1] = add32(r1150[c317900 * 500 + c317901 * 500 + c317902 * 1], r1149[i31789]);
        }
        /* neg [neg] -> r1151 */
        for (long i31792 = 0; i31792 < 40000; ++i31792) {
            r1151[i31792] = neg32(r1139[i31792]);
        }
        /* broadcast [broadcast_in_dim] -> r1152 */
        for (long i31793 = 0; i31793 < 2500; ++i31793) {
            long t31795 = i31793;
            long c317940 = t31795 / 500; t31795 %= 500;
            long c317941 = t31795 / 500; t31795 %= 500;
            long c317942 = t31795 / 1; t31795 %= 1;
            long c317943 = t31795;
            r1152[i31793] = r1146[c317940 * 500 + c317942 * 1];
        }
        /* sub [sub] -> r1153 */
        for (long i31796 = 0; i31796 < 40000; ++i31796) {
            long t31798 = i31796;
            long c317970 = t31798 / 8000; t31798 %= 8000;
            long c317971 = t31798 / 8000; t31798 %= 8000;
            long c317972 = t31798 / 16; t31798 %= 16;
            long c317973 = t31798;
            r1153[i31796] = sub32(r1151[c317970 * 8000 + c317972 * 16 + c317973 * 1], r1152[c317970 * 500 + c317972 * 1]);
        }
        /* max [max] -> r1154 */
        for (long i31799 = 0; i31799 < 40000; ++i31799) {
            r1154[i31799] = max32(r1153[i31799], r14[0]);
        }
        /* reduce_sum [reduce_sum] -> r1155 */
        for (long i31800 = 0; i31800 < 2500; ++i31800) {
            r1155[i31800] = 0;
        }
        for (long i31801 = 0; i31801 < 40000; ++i31801) {
            long t31803 = i31801;
            long c318020 = t31803 / 8000; t31803 %= 8000;
            long c318021 = t31803 / 8000; t31803 %= 8000;
            long c318022 = t31803 / 16; t31803 %= 16;
            long c318023 = t31803;
            r1155[c318020 * 500 + c318021 * 500 + c318022 * 1] = add32(r1155[c318020 * 500 + c318021 * 500 + c318022 * 1], r1154[i31801]);
        }
        /* add [add] -> r1156 */
        for (long i31804 = 0; i31804 < 2500; ++i31804) {
            r1156[i31804] = add32(r1150[i31804], r1155[i31804]);
        }
        /* gt [gt] -> r1157 */
        for (long i31805 = 0; i31805 < 2500; ++i31805) {
            r1157[i31805] = r1156[i31805] > r1140[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r1158 */
        for (long i31806 = 0; i31806 < 2500; ++i31806) {
            r1158[i31806] = r1157[i31806] == 0 ? r1142[i31806] : (r1146[i31806]);
        }
        /* select_n [select_n] -> r1159 */
        for (long i31807 = 0; i31807 < 2500; ++i31807) {
            r1159[i31807] = r1157[i31807] == 0 ? r1146[i31807] : (r1143[i31807]);
        }
        memcpy(r1141, r1144, sizeof(int32_t) * 1);
        memcpy(r1142, r1158, sizeof(int32_t) * 2500);
        memcpy(r1143, r1159, sizeof(int32_t) * 2500);
    }
    memcpy(r1160, r1141, sizeof(int32_t) * 1);
    memcpy(r1161, r1142, sizeof(int32_t) * 2500);
    memcpy(r1162, r1143, sizeof(int32_t) * 2500);
    /* sub [sub] -> r1163 */
    for (long i31808 = 0; i31808 < 2500; ++i31808) {
        r1163[i31808] = sub32(r1135[i31808], r1162[i31808]);
    }
    /* transpose [transpose] -> r1164 */
    for (long i31809 = 0; i31809 < 2500; ++i31809) {
        long t31811 = i31809;
        long c318100 = t31811 / 2500; t31811 %= 2500;
        long c318101 = t31811 / 500; t31811 %= 500;
        long c318102 = t31811;
        r1164[i31809] = r1163[c318100 * 500 + c318101 * 500 + c318102 * 1];
    }
    /* max [max] -> r1165 */
    for (long i31812 = 0; i31812 < 2500; ++i31812) {
        r1165[i31812] = max32(r1164[i31812], r14[0]);
    }
    /* reduce_sum [reduce_sum] -> r1166 */
    for (long i31813 = 0; i31813 < 5; ++i31813) {
        r1166[i31813] = 0;
    }
    for (long i31814 = 0; i31814 < 2500; ++i31814) {
        long t31816 = i31814;
        long c318150 = t31816 / 2500; t31816 %= 2500;
        long c318151 = t31816 / 500; t31816 %= 500;
        long c318152 = t31816;
        r1166[c318150 * 5 + c318151 * 1] = add32(r1166[c318150 * 5 + c318151 * 1], r1165[i31814]);
    }
    /* shl [shift_left] -> r1168 */
    for (long i31817 = 0; i31817 < 5; ++i31817) {
        r1168[i31817] = shl32(r1166[i31817], 5);
    }
    /* concat [concatenate] -> r1169 */
    for (long i31818 = 0; i31818 < 5; ++i31818) {
        long t31820 = i31818;
        long c318190 = t31820 / 5; t31820 %= 5;
        long c318191 = t31820;
        r1169[c318190 * 30 + (c318191 + 0) * 1] = r120[i31818];
    }
    for (long i31821 = 0; i31821 < 5; ++i31821) {
        long t31823 = i31821;
        long c318220 = t31823 / 5; t31823 %= 5;
        long c318221 = t31823;
        r1169[c318220 * 30 + (c318221 + 5) * 1] = r342[i31821];
    }
    for (long i31824 = 0; i31824 < 5; ++i31824) {
        long t31826 = i31824;
        long c318250 = t31826 / 5; t31826 %= 5;
        long c318251 = t31826;
        r1169[c318250 * 30 + (c318251 + 10) * 1] = r562[i31824];
    }
    for (long i31827 = 0; i31827 < 5; ++i31827) {
        long t31829 = i31827;
        long c318280 = t31829 / 5; t31829 %= 5;
        long c318281 = t31829;
        r1169[c318280 * 30 + (c318281 + 15) * 1] = r782[i31827];
    }
    for (long i31830 = 0; i31830 < 5; ++i31830) {
        long t31832 = i31830;
        long c318310 = t31832 / 5; t31832 %= 5;
        long c318311 = t31832;
        r1169[c318310 * 30 + (c318311 + 20) * 1] = r984[i31830];
    }
    for (long i31833 = 0; i31833 < 5; ++i31833) {
        long t31835 = i31833;
        long c318340 = t31835 / 5; t31835 %= 5;
        long c318341 = t31835;
        r1169[c318340 * 30 + (c318341 + 25) * 1] = r1168[i31833];
    }
    /* mov [device_put] -> r1170 */
    memcpy(r1170, r3, sizeof(int32_t) * 30);
    /* broadcast [broadcast_in_dim] -> r1171 */
    for (long i31836 = 0; i31836 < 30; ++i31836) {
        long t31838 = i31836;
        long c318370 = t31838 / 30; t31838 %= 30;
        long c318371 = t31838;
        r1171[i31836] = r1170[c318371 * 1];
    }
    /* sub [sub] -> r1172 */
    for (long i31839 = 0; i31839 < 30; ++i31839) {
        r1172[i31839] = sub32(r1169[i31839], r1171[i31839]);
    }
    /* mov [device_put] -> r1173 */
    memcpy(r1173, r4, sizeof(int32_t) * 30);
    /* ge [ge] -> r1174 */
    for (long i31840 = 0; i31840 < 30; ++i31840) {
        r1174[i31840] = r1173[i31840] >= r14[0] ? 1 : 0;
    }
    /* max [max] -> r1175 */
    for (long i31841 = 0; i31841 < 30; ++i31841) {
        r1175[i31841] = max32(r1173[i31841], r14[0]);
    }
    /* broadcast [broadcast_in_dim] -> r1176 */
    for (long i31842 = 0; i31842 < 30; ++i31842) {
        long t31844 = i31842;
        long c318430 = t31844 / 30; t31844 %= 30;
        long c318431 = t31844;
        r1176[i31842] = r1175[c318431 * 1];
    }
    /* shl [shift_left] -> r1177 */
    for (long i31845 = 0; i31845 < 30; ++i31845) {
        r1177[i31845] = shl32(r1172[i31845], r1176[i31845]);
    }
    /* neg [neg] -> r1178 */
    for (long i31846 = 0; i31846 < 30; ++i31846) {
        r1178[i31846] = neg32(r1173[i31846]);
    }
    /* max [max] -> r1179 */
    for (long i31847 = 0; i31847 < 30; ++i31847) {
        r1179[i31847] = max32(r1178[i31847], r14[0]);
    }
    /* broadcast [broadcast_in_dim] -> r1180 */
    for (long i31848 = 0; i31848 < 30; ++i31848) {
        long t31850 = i31848;
        long c318490 = t31850 / 30; t31850 %= 30;
        long c318491 = t31850;
        r1180[i31848] = r1179[c318491 * 1];
    }
    /* shra [shift_right_arithmetic] -> r1181 */
    for (long i31851 = 0; i31851 < 30; ++i31851) {
        r1181[i31851] = asr32(r1172[i31851], r1180[i31851]);
    }
    /* broadcast [broadcast_in_dim] -> r1182 */
    for (long i31852 = 0; i31852 < 30; ++i31852) {
        long t31854 = i31852;
        long c318530 = t31854 / 30; t31854 %= 30;
        long c318531 = t31854;
        r1182[i31852] = r1174[c318531 * 1];
    }
    /* select_n [select_n] -> r1183 */
    for (long i31855 = 0; i31855 < 30; ++i31855) {
        r1183[i31855] = r1182[i31855] == 0 ? r1181[i31855] : (r1177[i31855]);
    }
    /* mov [device_put] -> r1184 */
    memcpy(r1184, r5, sizeof(int32_t) * 30);
    /* ge [ge] -> r1185 */
    for (long i31856 = 0; i31856 < 30; ++i31856) {
        r1185[i31856] = r1184[i31856] >= r14[0] ? 1 : 0;
    }
    /* max [max] -> r1186 */
    for (long i31857 = 0; i31857 < 30; ++i31857) {
        r1186[i31857] = max32(r1184[i31857], r14[0]);
    }
    /* broadcast [broadcast_in_dim] -> r1187 */
    for (long i31858 = 0; i31858 < 30; ++i31858) {
        long t31860 = i31858;
        long c318590 = t31860 / 30; t31860 %= 30;
        long c318591 = t31860;
        r1187[i31858] = r1186[c318591 * 1];
    }
    /* shl [shift_left] -> r1188 */
    for (long i31861 = 0; i31861 < 30; ++i31861) {
        r1188[i31861] = shl32(r1172[i31861], r1187[i31861]);
    }
    /* neg [neg] -> r1189 */
    for (long i31862 = 0; i31862 < 30; ++i31862) {
        r1189[i31862] = neg32(r1184[i31862]);
    }
    /* max [max] -> r1190 */
    for (long i31863 = 0; i31863 < 30; ++i31863) {
        r1190[i31863] = max32(r1189[i31863], r14[0]);
    }
    /* broadcast [broadcast_in_dim] -> r1191 */
    for (long i31864 = 0; i31864 < 30; ++i31864) {
        long t31866 = i31864;
        long c318650 = t31866 / 30; t31866 %= 30;
        long c318651 = t31866;
        r1191[i31864] = r1190[c318651 * 1];
    }
    /* shra [shift_right_arithmetic] -> r1192 */
    for (long i31867 = 0; i31867 < 30; ++i31867) {
        r1192[i31867] = asr32(r1172[i31867], r1191[i31867]);
    }
    /* broadcast [broadcast_in_dim] -> r1193 */
    for (long i31868 = 0; i31868 < 30; ++i31868) {
        long t31870 = i31868;
        long c318690 = t31870 / 30; t31870 %= 30;
        long c318691 = t31870;
        r1193[i31868] = r1185[c318691 * 1];
    }
    /* select_n [select_n] -> r1194 */
    for (long i31871 = 0; i31871 < 30; ++i31871) {
        r1194[i31871] = r1193[i31871] == 0 ? r1192[i31871] : (r1188[i31871]);
    }
    /* mov [device_put] -> r1195 */
    memcpy(r1195, r3, sizeof(int32_t) * 30);
    /* gt [gt] -> r1196 */
    for (long i31872 = 0; i31872 < 30; ++i31872) {
        r1196[i31872] = r1195[i31872] > r14[0] ? 1 : 0;
    }
    /* add [add] -> r1197 */
    for (long i31873 = 0; i31873 < 30; ++i31873) {
        r1197[i31873] = add32(r1183[i31873], r1194[i31873]);
    }
    /* lt [lt] -> r1198 */
    for (long i31874 = 0; i31874 < 30; ++i31874) {
        r1198[i31874] = r1195[i31874] < r14[0] ? 1 : 0;
    }
    /* sub [sub] -> r1199 */
    for (long i31875 = 0; i31875 < 30; ++i31875) {
        r1199[i31875] = sub32(r1183[i31875], r1194[i31875]);
    }
    /* broadcast [broadcast_in_dim] -> r1200 */
    for (long i31876 = 0; i31876 < 30; ++i31876) {
        long t31878 = i31876;
        long c318770 = t31878 / 30; t31878 %= 30;
        long c318771 = t31878;
        r1200[i31876] = r1198[c318771 * 1];
    }
    /* select_n [select_n] -> r1201 */
    for (long i31879 = 0; i31879 < 30; ++i31879) {
        r1201[i31879] = r1200[i31879] == 0 ? r1183[i31879] : (r1199[i31879]);
    }
    /* broadcast [broadcast_in_dim] -> r1202 */
    for (long i31880 = 0; i31880 < 30; ++i31880) {
        long t31882 = i31880;
        long c318810 = t31882 / 30; t31882 %= 30;
        long c318811 = t31882;
        r1202[i31880] = r1196[c318811 * 1];
    }
    /* select_n [select_n] -> r1203 */
    for (long i31883 = 0; i31883 < 30; ++i31883) {
        r1203[i31883] = r1202[i31883] == 0 ? r1201[i31883] : (r1197[i31883]);
    }
    /* convert [convert_element_type] -> r1204 */
    for (long i31884 = 0; i31884 < 1; ++i31884) {
        r1204[i31884] = (int32_t)r227[0];
    }
    /* max [max] -> r1205 */
    for (long i31885 = 0; i31885 < 30; ++i31885) {
        r1205[i31885] = max32(r1204[0], r1203[i31885]);
    }
    /* convert [convert_element_type] -> r1206 */
    for (long i31886 = 0; i31886 < 1; ++i31886) {
        r1206[i31886] = (int32_t)r228[0];
    }
    /* min [min] -> r1207 */
    for (long i31887 = 0; i31887 < 30; ++i31887) {
        r1207[i31887] = min32(r1206[0], r1205[i31887]);
    }
    /* shl [shift_left] -> r1208 */
    for (long i31888 = 0; i31888 < 30; ++i31888) {
        r1208[i31888] = shl32(r1207[i31888], 1);
    }
    /* broadcast [broadcast_in_dim] -> r1209 */
    for (long i31889 = 0; i31889 < 30; ++i31889) {
        long t31891 = i31889;
        long c318900 = t31891 / 30; t31891 %= 30;
        long c318901 = t31891 / 1; t31891 %= 1;
        long c318902 = t31891;
        r1209[i31889] = r1208[c318901 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r1210 */
    for (long i31892 = 0; i31892 < 30; ++i31892) {
        long t31894 = i31892;
        long c318930 = t31894 / 30; t31894 %= 30;
        long c318931 = t31894 / 1; t31894 %= 1;
        long c318932 = t31894;
        r1210[i31892] = r1208[c318931 * 1];
    }
    /* neg [neg] -> r1211 */
    for (long i31895 = 0; i31895 < 30; ++i31895) {
        r1211[i31895] = neg32(r1210[i31895]);
    }
    /* mov [device_put] -> r1212 */
    memcpy(r1212, r6, sizeof(int32_t) * 300);
    /* mov [device_put] -> r1213 */
    memcpy(r1213, r7, sizeof(int32_t) * 300);
    /* broadcast [broadcast_in_dim] -> r1214 */
    for (long i31896 = 0; i31896 < 300; ++i31896) {
        long t31898 = i31896;
        long c318970 = t31898 / 300; t31898 %= 300;
        long c318971 = t31898 / 10; t31898 %= 10;
        long c318972 = t31898;
        r1214[i31896] = r1212[c318971 * 10 + c318972 * 1];
    }
    /* add [add] -> r1215 */
    for (long i31899 = 0; i31899 < 300; ++i31899) {
        long t31901 = i31899;
        long c319000 = t31901 / 300; t31901 %= 300;
        long c319001 = t31901 / 10; t31901 %= 10;
        long c319002 = t31901;
        r1215[i31899] = add32(r1214[c319001 * 10 + c319002 * 1], r1209[c319001 * 1]);
    }
    /* convert [convert_element_type] -> r1216 */
    for (long i31902 = 0; i31902 < 1; ++i31902) {
        r1216[i31902] = (int32_t)r46[0];
    }
    /* max [max] -> r1217 */
    for (long i31903 = 0; i31903 < 300; ++i31903) {
        r1217[i31903] = max32(r1216[0], r1215[i31903]);
    }
    /* convert [convert_element_type] -> r1218 */
    for (long i31904 = 0; i31904 < 1; ++i31904) {
        r1218[i31904] = (int32_t)r47[0];
    }
    /* min [min] -> r1219 */
    for (long i31905 = 0; i31905 < 300; ++i31905) {
        r1219[i31905] = min32(r1218[0], r1217[i31905]);
    }
    /* broadcast [broadcast_in_dim] -> r1220 */
    for (long i31906 = 0; i31906 < 300; ++i31906) {
        long t31908 = i31906;
        long c319070 = t31908 / 300; t31908 %= 300;
        long c319071 = t31908 / 10; t31908 %= 10;
        long c319072 = t31908;
        r1220[i31906] = r1213[c319071 * 10 + c319072 * 1];
    }
    /* add [add] -> r1221 */
    for (long i31909 = 0; i31909 < 300; ++i31909) {
        long t31911 = i31909;
        long c319100 = t31911 / 300; t31911 %= 300;
        long c319101 = t31911 / 10; t31911 %= 10;
        long c319102 = t31911;
        r1221[i31909] = add32(r1220[c319101 * 10 + c319102 * 1], r1211[c319101 * 1]);
    }
    /* convert [convert_element_type] -> r1222 */
    for (long i31912 = 0; i31912 < 1; ++i31912) {
        r1222[i31912] = (int32_t)r46[0];
    }
    /* max [max] -> r1223 */
    for (long i31913 = 0; i31913 < 300; ++i31913) {
        r1223[i31913] = max32(r1222[0], r1221[i31913]);
    }
    /* convert [convert_element_type] -> r1224 */
    for (long i31914 = 0; i31914 < 1; ++i31914) {
        r1224[i31914] = (int32_t)r47[0];
    }
    /* min [min] -> r1225 */
    for (long i31915 = 0; i31915 < 300; ++i31915) {
        r1225[i31915] = min32(r1224[0], r1223[i31915]);
    }
    /* concat [concatenate] -> r1226 */
    for (long i31916 = 0; i31916 < 300; ++i31916) {
        long t31918 = i31916;
        long c319170 = t31918 / 300; t31918 %= 300;
        long c319171 = t31918 / 10; t31918 %= 10;
        long c319172 = t31918;
        r1226[c319170 * 600 + (c319171 + 0) * 10 + c319172 * 1] = r1219[i31916];
    }
    for (long i31919 = 0; i31919 < 300; ++i31919) {
        long t31921 = i31919;
        long c319200 = t31921 / 300; t31921 %= 300;
        long c319201 = t31921 / 10; t31921 %= 10;
        long c319202 = t31921;
        r1226[c319200 * 600 + (c319201 + 30) * 10 + c319202 * 1] = r1225[i31919];
    }
    /* mov [device_put] -> r1227 */
    memcpy(r1227, r8, sizeof(int32_t) * 10);
    /* broadcast [broadcast_in_dim] -> r1228 */
    for (long i31922 = 0; i31922 < 10; ++i31922) {
        long t31924 = i31922;
        long c319230 = t31924 / 10; t31924 %= 10;
        long c319231 = t31924 / 10; t31924 %= 10;
        long c319232 = t31924;
        r1228[i31922] = r1227[c319232 * 1];
    }
    /* concat [concatenate] -> r1229 */
    for (long i31925 = 0; i31925 < 600; ++i31925) {
        long t31927 = i31925;
        long c319260 = t31927 / 600; t31927 %= 600;
        long c319261 = t31927 / 10; t31927 %= 10;
        long c319262 = t31927;
        r1229[c319260 * 610 + (c319261 + 0) * 10 + c319262 * 1] = r1226[i31925];
    }
    for (long i31928 = 0; i31928 < 10; ++i31928) {
        long t31930 = i31928;
        long c319290 = t31930 / 10; t31930 %= 10;
        long c319291 = t31930 / 10; t31930 %= 10;
        long c319292 = t31930;
        r1229[c319290 * 610 + (c319291 + 60) * 10 + c319292 * 1] = r1228[i31928];
    }
    /* transpose [transpose] -> r1230 */
    for (long i31931 = 0; i31931 < 610; ++i31931) {
        long t31933 = i31931;
        long c319320 = t31933 / 610; t31933 %= 610;
        long c319321 = t31933 / 61; t31933 %= 61;
        long c319322 = t31933;
        r1230[i31931] = r1229[c319320 * 610 + c319321 * 1 + c319322 * 10];
    }
    /* reduce_max [reduce_max] -> r1231 */
    for (long i31934 = 0; i31934 < 10; ++i31934) {
        r1231[i31934] = (-2147483647 - 1);
    }
    for (long i31935 = 0; i31935 < 610; ++i31935) {
        long t31937 = i31935;
        long c319360 = t31937 / 610; t31937 %= 610;
        long c319361 = t31937 / 61; t31937 %= 61;
        long c319362 = t31937;
        r1231[c319360 * 10 + c319361 * 1] = max32(r1231[c319360 * 10 + c319361 * 1], r1230[i31935]);
    }
    /* sub [sub] -> r1233 */
    for (long i31938 = 0; i31938 < 10; ++i31938) {
        r1233[i31938] = sub32(r1231[i31938], r1232[0]);
    }
    /* loop [scan] -> r1249 */
    memcpy(r1234, r1230, sizeof(int32_t) * 610);
    memcpy(r1235, r1232, sizeof(int32_t) * 1);
    memcpy(r1236, r14, sizeof(int32_t) * 1);
    memcpy(r1237, r1233, sizeof(int32_t) * 10);
    memcpy(r1238, r1231, sizeof(int32_t) * 10);
    for (long t31939 = 0; t31939 < 11; ++t31939) {
        /* add [add] -> r1239 */
        for (long i32940 = 0; i32940 < 1; ++i32940) {
            r1239[i32940] = add32(r1236[0], r9[0]);
        }
        /* add [add] -> r1240 */
        for (long i32941 = 0; i32941 < 10; ++i32941) {
            r1240[i32941] = add32(r1237[i32941], r1238[i32941]);
        }
        /* shra [shift_right_arithmetic] -> r1241 */
        for (long i32942 = 0; i32942 < 10; ++i32942) {
            r1241[i32942] = asr32(r1240[i32942], 1);
        }
        /* broadcast [broadcast_in_dim] -> r1242 */
        for (long i32943 = 0; i32943 < 10; ++i32943) {
            long t32945 = i32943;
            long c329440 = t32945 / 10; t32945 %= 10;
            long c329441 = t32945 / 1; t32945 %= 1;
            long c329442 = t32945;
            r1242[i32943] = r1241[c329441 * 1];
        }
        /* sub [sub] -> r1243 */
        for (long i32946 = 0; i32946 < 610; ++i32946) {
            long t32948 = i32946;
            long c329470 = t32948 / 610; t32948 %= 610;
            long c329471 = t32948 / 61; t32948 %= 61;
            long c329472 = t32948;
            r1243[i32946] = sub32(r1234[c329471 * 61 + c329472 * 1], r1242[c329471 * 1]);
        }
        /* max [max] -> r1244 */
        for (long i32949 = 0; i32949 < 610; ++i32949) {
            r1244[i32949] = max32(r1243[i32949], r14[0]);
        }
        /* reduce_sum [reduce_sum] -> r1245 */
        for (long i32950 = 0; i32950 < 10; ++i32950) {
            r1245[i32950] = 0;
        }
        for (long i32951 = 0; i32951 < 610; ++i32951) {
            long t32953 = i32951;
            long c329520 = t32953 / 610; t32953 %= 610;
            long c329521 = t32953 / 61; t32953 %= 61;
            long c329522 = t32953;
            r1245[c329520 * 10 + c329521 * 1] = add32(r1245[c329520 * 10 + c329521 * 1], r1244[i32951]);
        }
        /* gt [gt] -> r1246 */
        for (long i32954 = 0; i32954 < 10; ++i32954) {
            r1246[i32954] = r1245[i32954] > r1235[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r1247 */
        for (long i32955 = 0; i32955 < 10; ++i32955) {
            r1247[i32955] = r1246[i32955] == 0 ? r1237[i32955] : (r1241[i32955]);
        }
        /* select_n [select_n] -> r1248 */
        for (long i32956 = 0; i32956 < 10; ++i32956) {
            r1248[i32956] = r1246[i32956] == 0 ? r1241[i32956] : (r1238[i32956]);
        }
        memcpy(r1236, r1239, sizeof(int32_t) * 1);
        memcpy(r1237, r1247, sizeof(int32_t) * 10);
        memcpy(r1238, r1248, sizeof(int32_t) * 10);
    }
    memcpy(r1249, r1236, sizeof(int32_t) * 1);
    memcpy(r1250, r1237, sizeof(int32_t) * 10);
    memcpy(r1251, r1238, sizeof(int32_t) * 10);
    /* broadcast [broadcast_in_dim] -> r1252 */
    for (long i32957 = 0; i32957 < 300; ++i32957) {
        long t32959 = i32957;
        long c329580 = t32959 / 300; t32959 %= 300;
        long c329581 = t32959 / 10; t32959 %= 10;
        long c329582 = t32959;
        r1252[i32957] = r1213[c329581 * 10 + c329582 * 1];
    }
    /* add [add] -> r1253 */
    for (long i32960 = 0; i32960 < 300; ++i32960) {
        long t32962 = i32960;
        long c329610 = t32962 / 300; t32962 %= 300;
        long c329611 = t32962 / 10; t32962 %= 10;
        long c329612 = t32962;
        r1253[i32960] = add32(r1252[c329611 * 10 + c329612 * 1], r1209[c329611 * 1]);
    }
    /* convert [convert_element_type] -> r1254 */
    for (long i32963 = 0; i32963 < 1; ++i32963) {
        r1254[i32963] = (int32_t)r46[0];
    }
    /* max [max] -> r1255 */
    for (long i32964 = 0; i32964 < 300; ++i32964) {
        r1255[i32964] = max32(r1254[0], r1253[i32964]);
    }
    /* convert [convert_element_type] -> r1256 */
    for (long i32965 = 0; i32965 < 1; ++i32965) {
        r1256[i32965] = (int32_t)r47[0];
    }
    /* min [min] -> r1257 */
    for (long i32966 = 0; i32966 < 300; ++i32966) {
        r1257[i32966] = min32(r1256[0], r1255[i32966]);
    }
    /* broadcast [broadcast_in_dim] -> r1258 */
    for (long i32967 = 0; i32967 < 300; ++i32967) {
        long t32969 = i32967;
        long c329680 = t32969 / 300; t32969 %= 300;
        long c329681 = t32969 / 10; t32969 %= 10;
        long c329682 = t32969;
        r1258[i32967] = r1212[c329681 * 10 + c329682 * 1];
    }
    /* add [add] -> r1259 */
    for (long i32970 = 0; i32970 < 300; ++i32970) {
        long t32972 = i32970;
        long c329710 = t32972 / 300; t32972 %= 300;
        long c329711 = t32972 / 10; t32972 %= 10;
        long c329712 = t32972;
        r1259[i32970] = add32(r1258[c329711 * 10 + c329712 * 1], r1211[c329711 * 1]);
    }
    /* convert [convert_element_type] -> r1260 */
    for (long i32973 = 0; i32973 < 1; ++i32973) {
        r1260[i32973] = (int32_t)r46[0];
    }
    /* max [max] -> r1261 */
    for (long i32974 = 0; i32974 < 300; ++i32974) {
        r1261[i32974] = max32(r1260[0], r1259[i32974]);
    }
    /* convert [convert_element_type] -> r1262 */
    for (long i32975 = 0; i32975 < 1; ++i32975) {
        r1262[i32975] = (int32_t)r47[0];
    }
    /* min [min] -> r1263 */
    for (long i32976 = 0; i32976 < 300; ++i32976) {
        r1263[i32976] = min32(r1262[0], r1261[i32976]);
    }
    /* concat [concatenate] -> r1264 */
    for (long i32977 = 0; i32977 < 300; ++i32977) {
        long t32979 = i32977;
        long c329780 = t32979 / 300; t32979 %= 300;
        long c329781 = t32979 / 10; t32979 %= 10;
        long c329782 = t32979;
        r1264[c329780 * 600 + (c329781 + 0) * 10 + c329782 * 1] = r1257[i32977];
    }
    for (long i32980 = 0; i32980 < 300; ++i32980) {
        long t32982 = i32980;
        long c329810 = t32982 / 300; t32982 %= 300;
        long c329811 = t32982 / 10; t32982 %= 10;
        long c329812 = t32982;
        r1264[c329810 * 600 + (c329811 + 30) * 10 + c329812 * 1] = r1263[i32980];
    }
    /* mov [device_put] -> r1265 */
    memcpy(r1265, r8, sizeof(int32_t) * 10);
    /* broadcast [broadcast_in_dim] -> r1266 */
    for (long i32983 = 0; i32983 < 10; ++i32983) {
        long t32985 = i32983;
        long c329840 = t32985 / 10; t32985 %= 10;
        long c329841 = t32985 / 10; t32985 %= 10;
        long c329842 = t32985;
        r1266[i32983] = r1265[c329842 * 1];
    }
    /* concat [concatenate] -> r1267 */
    for (long i32986 = 0; i32986 < 600; ++i32986) {
        long t32988 = i32986;
        long c329870 = t32988 / 600; t32988 %= 600;
        long c329871 = t32988 / 10; t32988 %= 10;
        long c329872 = t32988;
        r1267[c329870 * 610 + (c329871 + 0) * 10 + c329872 * 1] = r1264[i32986];
    }
    for (long i32989 = 0; i32989 < 10; ++i32989) {
        long t32991 = i32989;
        long c329900 = t32991 / 10; t32991 %= 10;
        long c329901 = t32991 / 10; t32991 %= 10;
        long c329902 = t32991;
        r1267[c329900 * 610 + (c329901 + 60) * 10 + c329902 * 1] = r1266[i32989];
    }
    /* transpose [transpose] -> r1268 */
    for (long i32992 = 0; i32992 < 610; ++i32992) {
        long t32994 = i32992;
        long c329930 = t32994 / 610; t32994 %= 610;
        long c329931 = t32994 / 61; t32994 %= 61;
        long c329932 = t32994;
        r1268[i32992] = r1267[c329930 * 610 + c329931 * 1 + c329932 * 10];
    }
    /* reduce_max [reduce_max] -> r1269 */
    for (long i32995 = 0; i32995 < 10; ++i32995) {
        r1269[i32995] = (-2147483647 - 1);
    }
    for (long i32996 = 0; i32996 < 610; ++i32996) {
        long t32998 = i32996;
        long c329970 = t32998 / 610; t32998 %= 610;
        long c329971 = t32998 / 61; t32998 %= 61;
        long c329972 = t32998;
        r1269[c329970 * 10 + c329971 * 1] = max32(r1269[c329970 * 10 + c329971 * 1], r1268[i32996]);
    }
    /* sub [sub] -> r1270 */
    for (long i32999 = 0; i32999 < 10; ++i32999) {
        r1270[i32999] = sub32(r1269[i32999], r1232[0]);
    }
    /* loop [scan] -> r1286 */
    memcpy(r1271, r1268, sizeof(int32_t) * 610);
    memcpy(r1272, r1232, sizeof(int32_t) * 1);
    memcpy(r1273, r14, sizeof(int32_t) * 1);
    memcpy(r1274, r1270, sizeof(int32_t) * 10);
    memcpy(r1275, r1269, sizeof(int32_t) * 10);
    for (long t33000 = 0; t33000 < 11; ++t33000) {
        /* add [add] -> r1276 */
        for (long i34001 = 0; i34001 < 1; ++i34001) {
            r1276[i34001] = add32(r1273[0], r9[0]);
        }
        /* add [add] -> r1277 */
        for (long i34002 = 0; i34002 < 10; ++i34002) {
            r1277[i34002] = add32(r1274[i34002], r1275[i34002]);
        }
        /* shra [shift_right_arithmetic] -> r1278 */
        for (long i34003 = 0; i34003 < 10; ++i34003) {
            r1278[i34003] = asr32(r1277[i34003], 1);
        }
        /* broadcast [broadcast_in_dim] -> r1279 */
        for (long i34004 = 0; i34004 < 10; ++i34004) {
            long t34006 = i34004;
            long c340050 = t34006 / 10; t34006 %= 10;
            long c340051 = t34006 / 1; t34006 %= 1;
            long c340052 = t34006;
            r1279[i34004] = r1278[c340051 * 1];
        }
        /* sub [sub] -> r1280 */
        for (long i34007 = 0; i34007 < 610; ++i34007) {
            long t34009 = i34007;
            long c340080 = t34009 / 610; t34009 %= 610;
            long c340081 = t34009 / 61; t34009 %= 61;
            long c340082 = t34009;
            r1280[i34007] = sub32(r1271[c340081 * 61 + c340082 * 1], r1279[c340081 * 1]);
        }
        /* max [max] -> r1281 */
        for (long i34010 = 0; i34010 < 610; ++i34010) {
            r1281[i34010] = max32(r1280[i34010], r14[0]);
        }
        /* reduce_sum [reduce_sum] -> r1282 */
        for (long i34011 = 0; i34011 < 10; ++i34011) {
            r1282[i34011] = 0;
        }
        for (long i34012 = 0; i34012 < 610; ++i34012) {
            long t34014 = i34012;
            long c340130 = t34014 / 610; t34014 %= 610;
            long c340131 = t34014 / 61; t34014 %= 61;
            long c340132 = t34014;
            r1282[c340130 * 10 + c340131 * 1] = add32(r1282[c340130 * 10 + c340131 * 1], r1281[i34012]);
        }
        /* gt [gt] -> r1283 */
        for (long i34015 = 0; i34015 < 10; ++i34015) {
            r1283[i34015] = r1282[i34015] > r1272[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r1284 */
        for (long i34016 = 0; i34016 < 10; ++i34016) {
            r1284[i34016] = r1283[i34016] == 0 ? r1274[i34016] : (r1278[i34016]);
        }
        /* select_n [select_n] -> r1285 */
        for (long i34017 = 0; i34017 < 10; ++i34017) {
            r1285[i34017] = r1283[i34017] == 0 ? r1278[i34017] : (r1275[i34017]);
        }
        memcpy(r1273, r1276, sizeof(int32_t) * 1);
        memcpy(r1274, r1284, sizeof(int32_t) * 10);
        memcpy(r1275, r1285, sizeof(int32_t) * 10);
    }
    memcpy(r1286, r1273, sizeof(int32_t) * 1);
    memcpy(r1287, r1274, sizeof(int32_t) * 10);
    memcpy(r1288, r1275, sizeof(int32_t) * 10);
    /* broadcast [broadcast_in_dim] -> r1289 */
    for (long i34018 = 0; i34018 < 10; ++i34018) {
        long t34020 = i34018;
        long c340190 = t34020 / 10; t34020 %= 10;
        long c340191 = t34020 / 1; t34020 %= 1;
        long c340192 = t34020;
        r1289[i34018] = r1251[c340191 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r1290 */
    for (long i34021 = 0; i34021 < 10; ++i34021) {
        long t34023 = i34021;
        long c340220 = t34023 / 10; t34023 %= 10;
        long c340221 = t34023 / 1; t34023 %= 1;
        long c340222 = t34023;
        r1290[i34021] = r1288[c340221 * 1];
    }
    /* concat [concatenate] -> r1291 */
    for (long i34024 = 0; i34024 < 10; ++i34024) {
        long t34026 = i34024;
        long c340250 = t34026 / 10; t34026 %= 10;
        long c340251 = t34026 / 1; t34026 %= 1;
        long c340252 = t34026;
        r1291[c340250 * 20 + c340251 * 2 + (c340252 + 0) * 1] = r1289[i34024];
    }
    for (long i34027 = 0; i34027 < 10; ++i34027) {
        long t34029 = i34027;
        long c340280 = t34029 / 10; t34029 %= 10;
        long c340281 = t34029 / 1; t34029 %= 1;
        long c340282 = t34029;
        r1291[c340280 * 20 + c340281 * 2 + (c340282 + 1) * 1] = r1290[i34027];
    }
    /* reduce_max [reduce_max] -> r1292 */
    for (long i34030 = 0; i34030 < 10; ++i34030) {
        r1292[i34030] = (-2147483647 - 1);
    }
    for (long i34031 = 0; i34031 < 20; ++i34031) {
        long t34033 = i34031;
        long c340320 = t34033 / 20; t34033 %= 20;
        long c340321 = t34033 / 2; t34033 %= 2;
        long c340322 = t34033;
        r1292[c340320 * 10 + c340321 * 1] = max32(r1292[c340320 * 10 + c340321 * 1], r1291[i34031]);
    }
    /* sub [sub] -> r1294 */
    for (long i34034 = 0; i34034 < 10; ++i34034) {
        r1294[i34034] = sub32(r1292[i34034], r1293[0]);
    }
    /* loop [scan] -> r1310 */
    memcpy(r1295, r1291, sizeof(int32_t) * 20);
    memcpy(r1296, r1293, sizeof(int32_t) * 1);
    memcpy(r1297, r14, sizeof(int32_t) * 1);
    memcpy(r1298, r1294, sizeof(int32_t) * 10);
    memcpy(r1299, r1292, sizeof(int32_t) * 10);
    for (long t34035 = 0; t34035 < 8; ++t34035) {
        /* add [add] -> r1300 */
        for (long i35036 = 0; i35036 < 1; ++i35036) {
            r1300[i35036] = add32(r1297[0], r9[0]);
        }
        /* add [add] -> r1301 */
        for (long i35037 = 0; i35037 < 10; ++i35037) {
            r1301[i35037] = add32(r1298[i35037], r1299[i35037]);
        }
        /* shra [shift_right_arithmetic] -> r1302 */
        for (long i35038 = 0; i35038 < 10; ++i35038) {
            r1302[i35038] = asr32(r1301[i35038], 1);
        }
        /* broadcast [broadcast_in_dim] -> r1303 */
        for (long i35039 = 0; i35039 < 10; ++i35039) {
            long t35041 = i35039;
            long c350400 = t35041 / 10; t35041 %= 10;
            long c350401 = t35041 / 1; t35041 %= 1;
            long c350402 = t35041;
            r1303[i35039] = r1302[c350401 * 1];
        }
        /* sub [sub] -> r1304 */
        for (long i35042 = 0; i35042 < 20; ++i35042) {
            long t35044 = i35042;
            long c350430 = t35044 / 20; t35044 %= 20;
            long c350431 = t35044 / 2; t35044 %= 2;
            long c350432 = t35044;
            r1304[i35042] = sub32(r1295[c350431 * 2 + c350432 * 1], r1303[c350431 * 1]);
        }
        /* max [max] -> r1305 */
        for (long i35045 = 0; i35045 < 20; ++i35045) {
            r1305[i35045] = max32(r1304[i35045], r14[0]);
        }
        /* reduce_sum [reduce_sum] -> r1306 */
        for (long i35046 = 0; i35046 < 10; ++i35046) {
            r1306[i35046] = 0;
        }
        for (long i35047 = 0; i35047 < 20; ++i35047) {
            long t35049 = i35047;
            long c350480 = t35049 / 20; t35049 %= 20;
            long c350481 = t35049 / 2; t35049 %= 2;
            long c350482 = t35049;
            r1306[c350480 * 10 + c350481 * 1] = add32(r1306[c350480 * 10 + c350481 * 1], r1305[i35047]);
        }
        /* gt [gt] -> r1307 */
        for (long i35050 = 0; i35050 < 10; ++i35050) {
            r1307[i35050] = r1306[i35050] > r1296[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r1308 */
        for (long i35051 = 0; i35051 < 10; ++i35051) {
            r1308[i35051] = r1307[i35051] == 0 ? r1298[i35051] : (r1302[i35051]);
        }
        /* select_n [select_n] -> r1309 */
        for (long i35052 = 0; i35052 < 10; ++i35052) {
            r1309[i35052] = r1307[i35052] == 0 ? r1302[i35052] : (r1299[i35052]);
        }
        memcpy(r1297, r1300, sizeof(int32_t) * 1);
        memcpy(r1298, r1308, sizeof(int32_t) * 10);
        memcpy(r1299, r1309, sizeof(int32_t) * 10);
    }
    memcpy(r1310, r1297, sizeof(int32_t) * 1);
    memcpy(r1311, r1298, sizeof(int32_t) * 10);
    memcpy(r1312, r1299, sizeof(int32_t) * 10);
    /* sub [sub] -> r1313 */
    for (long i35053 = 0; i35053 < 10; ++i35053) {
        r1313[i35053] = sub32(r1251[i35053], r1312[i35053]);
    }
    /* max [max] -> r1314 */
    for (long i35054 = 0; i35054 < 10; ++i35054) {
        r1314[i35054] = max32(r1313[i35054], r14[0]);
    }
    /* sub [sub] -> r1315 */
    for (long i35055 = 0; i35055 < 10; ++i35055) {
        r1315[i35055] = sub32(r1288[i35055], r1312[i35055]);
    }
    /* max [max] -> r1316 */
    for (long i35056 = 0; i35056 < 10; ++i35056) {
        r1316[i35056] = max32(r1315[i35056], r14[0]);
    }
    /* sub [sub] -> r1317 */
    for (long i35057 = 0; i35057 < 10; ++i35057) {
        r1317[i35057] = sub32(r1314[i35057], r1316[i35057]);
    }
}

int main(int argc, char **argv) {
    if (argc != 3) { fprintf(stderr, "usage: %s in.bin out.bin\n", argv[0]); return 2; }
    FILE *fi = fopen(argv[1], "rb");
    if (!fi) { perror("in"); return 2; }
    if (fread(r0, sizeof(int32_t), 16000, fi) != 16000) { fprintf(stderr, "short read\n"); return 2; }
    fclose(fi);
    program_run();
    FILE *fo = fopen(argv[2], "wb");
    if (!fo) { perror("out"); return 2; }
    fwrite(r1317, sizeof(int32_t), 10, fo);
    fwrite(r1207, sizeof(int32_t), 30, fo);
    fwrite(r1169, sizeof(int32_t), 30, fo);
    fclose(fo);
    return 0;
}
